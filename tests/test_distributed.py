"""Multi-device behaviour, via subprocesses that force 8 host devices
(the main test process must keep the real single-device view).

The distributed-CC oracle test runs in the FAST tier (it is the only
coverage ``core.distributed`` gets outside ``-m slow``); the heavy
LM/GNN/elastic cases stay slow-marked."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    # inherit the parent env: a stripped PATH/env makes XLA's CPU client
    # stall for minutes on host introspection (observed 470s -> 1.2s for
    # the same program). XLA_FLAGS is overridden in-code above, before
    # the child imports jax.
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env=env, cwd=_REPO_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_cc_oracle_8dev_fast_tier():
    """Fast-tier coverage for ``core.distributed`` on 8 forced host
    devices: the sharded-DeviceGraph path must equal both the
    union-find oracle and the single-device engine, including edge
    counts that do NOT divide into 8 shards (star: 12 edges, cliques:
    30 — ``DeviceGraph.shard`` pads with (0,0) no-ops)."""
    out = run_sub("""
        from repro.core.cc import connected_components
        from repro.core.distributed import (distributed_connected_components,
                                            make_distributed_cc)
        from repro.core.unionfind import connected_components_oracle
        from repro.graphs.device import DeviceGraph
        from repro.graphs.generators import (disjoint_cliques, grid_road,
                                             rmat, star)
        assert len(jax.devices()) == 8
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        # star: 12 edges, cliques: 30 — neither divides into 8 shards
        # (DeviceGraph.shard pads with (0,0) no-ops); rmat/grid divide.
        cases = (rmat(6, 4, seed=2), grid_road(7, seed=3), star(13),
                 disjoint_cliques(3, 5, seed=1))
        assert any(g.num_edges % 8 for g in cases)
        for g in cases:
            dg = DeviceGraph.from_host(g).shard(mesh, ("data",))
            assert dg.edges.shape[0] % 8 == 0
            fn = make_distributed_cc(dg, mesh, ("data",))
            labels = np.asarray(fn(dg))
            want = connected_components_oracle(g.edges, g.num_nodes)
            single = np.asarray(
                connected_components(g.edges, g.num_nodes).labels)
            np.testing.assert_array_equal(labels, want, err_msg=g.name)
            np.testing.assert_array_equal(labels, single, err_msg=g.name)
        # convenience wrapper shards internally
        g = star(13)
        np.testing.assert_array_equal(
            np.asarray(distributed_connected_components(g, mesh)),
            connected_components_oracle(g.edges, g.num_nodes))
        print("DIST_FAST_OK")
    """)
    assert "DIST_FAST_OK" in out


@pytest.mark.slow
def test_distributed_cc_matches_oracle():
    out = run_sub("""
        from repro.core.distributed import distributed_connected_components
        from repro.core.unionfind import connected_components_oracle
        from repro.graphs.generators import rmat, grid_road
        mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4, 2),
                                 ("data", "model"))
        for g in (rmat(7, 4, seed=0), grid_road(12, seed=1)):
            labels = distributed_connected_components(
                g, mesh, axis_names=("data", "model"))
            want = connected_components_oracle(g.edges, g.num_nodes)
            np.testing.assert_array_equal(np.asarray(labels), want)
        print("DIST_CC_OK")
    """)
    assert "DIST_CC_OK" in out


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    """The same train step, single device vs 4x2 mesh: identical loss
    (the distribution layer must not change numerics)."""
    out = run_sub("""
        from repro.configs import get_arch
        from repro.models import transformer as T
        from repro.train import train_state
        from repro.train.optimizer import adamw, AdamWConfig
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_arch("qwen2.5-32b").make_smoke_config()
        params = T.init(jax.random.PRNGKey(0), cfg)
        opt = adamw(AdamWConfig(lr=1e-3))
        state = train_state.create(params, opt)
        step = train_state.make_train_step(
            lambda p, b: T.loss_fn(p, b, cfg), opt)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 1,
                                  cfg.vocab)
        batch = {"tokens": toks}
        _, m1 = jax.jit(step)(jax.tree.map(jnp.copy, state), batch)

        mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(4, 2),
                                 ("data", "model"))
        pspec = T.param_spec(cfg, ("data",))
        state_spec = {"params": pspec,
                      "opt": {k: pspec for k in state["opt"]},
                      "step": P()}
        named = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             state_spec,
                             is_leaf=lambda x: isinstance(x, P))
        bspec = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             T.batch_spec(("data",)),
                             is_leaf=lambda x: isinstance(x, P))
        with mesh:
            sharded = jax.jit(step, in_shardings=(named, bspec))
            _, m2 = sharded(jax.device_put(state, named),
                            jax.device_put(batch, bspec))
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-3, (float(m1["loss"]), float(m2["loss"]))
        print("SHARDED_LM_OK", d)
    """)
    assert "SHARDED_LM_OK" in out


@pytest.mark.slow
def test_nequip_shardmap_step_matches_single_device():
    out = run_sub("""
        import dataclasses as dc
        from repro.configs import get_arch
        from repro.models.gnn import nequip
        from repro.launch.steps import build_cell
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        cfg = get_arch("nequip").make_smoke_config()
        rng = np.random.default_rng(0)
        V, E, G = 32, 64, 4
        batch = {
          "positions": jnp.asarray(rng.standard_normal((V, 3)) * 1.5,
                                   jnp.float32),
          "species": jnp.asarray(rng.integers(0, cfg.n_species, V),
                                 jnp.int32),
          "src": jnp.asarray(rng.integers(0, V, E), jnp.int32),
          "dst": jnp.asarray(rng.integers(0, V, E), jnp.int32),
          "graph_ids": jnp.asarray(np.repeat(np.arange(G), V // G),
                                   jnp.int32),
          "energy": jnp.asarray(rng.standard_normal(G), jnp.float32),
        }
        params = nequip.init(jax.random.PRNGKey(0), cfg)
        base = float(nequip.loss_fn(params, batch, cfg))

        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        dcfg = dc.replace(cfg, dist_axes=("data",))
        def local_loss(p, b):
            l = nequip.loss_fn(p, b, dcfg)
            return jax.lax.pmean(l, ("data",))
        bspec = {k: (P("data") if k in ("src", "dst") else
                     P("data", *(None,) * (v.ndim - 1))
                     if v.shape[0] == V else P())
                 for k, v in batch.items()}
        f = shard_map(local_loss, mesh=mesh,
                      in_specs=(jax.tree.map(lambda _: P(), params),
                                bspec),
                      out_specs=P(), check_rep=False)
        with mesh:
            dist = float(f(params, batch))
        assert abs(dist - base) < 1e-4, (base, dist)
        print("NEQUIP_SHMAP_OK", abs(dist - base))
    """)
    assert "NEQUIP_SHMAP_OK" in out


@pytest.mark.slow
def test_compressed_psum_multidevice():
    out = run_sub("""
        from repro.train.compression import compressed_psum, zero_residual
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("d",))
        g = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 100.
        res = jnp.zeros((8, 16), jnp.float32)
        def f(gl, rl):
            out, nr = compressed_psum({"g": gl}, {"g": rl}, "d")
            return out["g"], nr["g"]
        mean, _ = shard_map(f, mesh=mesh, in_specs=(P("d"), P("d")),
                            out_specs=(P("d"), P("d")),
                            check_rep=False)(g, res)
        # per-shard mean over 8 single-row shards: each row reduces to
        # the mean of ... all rows; compare against exact
        exact = jnp.broadcast_to(g.mean(axis=0, keepdims=True), g.shape)
        err = float(jnp.abs(mean - exact).max())
        assert err < 2e-2, err
        print("CPSUM_OK", err)
    """)
    assert "CPSUM_OK" in out


@pytest.mark.slow
def test_elastic_rescale_roundtrip(tmp_path):
    """Checkpoint on a 8-device mesh, restore on 1 device (subprocess
    boundary is the 'cluster change')."""
    out = run_sub(f"""
        from repro.train import checkpoint as ck
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        sh = NamedSharding(mesh, P("data"))
        state = {{"w": jax.device_put(jnp.arange(64, dtype=jnp.float32),
                                      sh)}}
        ck.save("{tmp_path}", state, 5)
        print("SAVED")
    """)
    assert "SAVED" in out
    # restore in THIS process (1 device)
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.train import checkpoint as ck
    like = {"w": jax.ShapeDtypeStruct((64,), jnp.float32)}
    restored = ck.restore(str(tmp_path), like=like)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64, dtype=np.float32))
