"""The repro.api facade (ISSUE 5 / DESIGN.md §10).

Covers the four tentpole claims:

  * **surface stability** — ``repro.api.__all__`` and the ``BACKENDS``
    registry (names + capability matrix) are snapshot-pinned, so any
    accidental drift of the public surface fails loudly;
  * **inspectable plans** — ``plan.explain()`` reports the backend that
    ``policy.select_method`` actually chooses (asserted over corpus
    graphs), forced-backend overrides round-trip, and planning touches
    the device not at all;
  * **the Solver session** — static solve, insert, delete, and every
    ``queries.py`` lookup agree with the dynamic oracle; the
    steady-state mutation path is transfer-free under
    ``jax.transfer_guard("disallow")`` when driven via the facade;
  * **single counting implementation** — ``cc.num_components``,
    ``IncrementalCC.num_components``, ``Solver.num_components`` and the
    registry all delegate to ``connectivity.queries.count_components``.
"""
import numpy as np
import pytest

from _graphgen import corpus
from repro import __version__
from repro.api import (BACKENDS, Capabilities, ExecutionPlan, Solver,
                       available_backends, capability_matrix, get_backend,
                       register_backend, solve)
from repro.connectivity import policy
from repro.core.unionfind import (DynamicConnectivityOracle,
                                  connected_components_oracle)

import repro.api as api_mod  # noqa: E402  (module-object identity checks)


# ---------------------------------------------------------------------------
# Public-API stability (CI satellite): snapshot, fail on surface drift
# ---------------------------------------------------------------------------

EXPECTED_ALL = [
    "BACKENDS", "Backend", "CCResult", "Capabilities", "DeviceGraph",
    "ExecutionPlan", "Solver", "WorkCounters", "available_backends",
    "capability_matrix", "get_backend", "register_backend", "solve",
]

EXPECTED_BACKENDS = [
    "adaptive", "atomic_hook", "batched", "distributed", "dynamic",
    "hostloop", "incremental", "labelprop", "multijump", "pallas",
    "pallas_fused", "sampled", "sampled_fused", "soman",
]

# (static, batched, streaming, deletions, sharded, device_loop,
#  bit_exact_counters, spanning_forest, maintained_forest) per backend —
# the DESIGN.md §10 capability matrix (maintained_forest = keeps the
# forest as a device resident across mutations, DESIGN.md §14)
EXPECTED_CAPABILITIES = {
    "soman":         (1, 0, 0, 0, 0, 1, 1, 1, 0),
    "multijump":     (1, 0, 0, 0, 0, 1, 1, 1, 0),
    "atomic_hook":   (1, 0, 0, 0, 0, 1, 1, 1, 0),
    "adaptive":      (1, 0, 0, 0, 0, 1, 1, 1, 0),
    "labelprop":     (1, 0, 0, 0, 0, 1, 1, 0, 0),
    "pallas":        (1, 0, 0, 0, 0, 1, 0, 0, 0),
    "pallas_fused":  (1, 0, 0, 0, 0, 1, 1, 0, 0),
    "sampled":       (1, 0, 0, 0, 0, 1, 1, 1, 0),
    "sampled_fused": (1, 0, 0, 0, 0, 1, 1, 0, 0),
    "hostloop":      (1, 0, 0, 0, 0, 0, 0, 0, 0),
    "batched":       (1, 1, 0, 0, 0, 1, 1, 0, 0),
    "incremental":   (1, 0, 1, 0, 0, 1, 1, 0, 0),
    "dynamic":       (1, 0, 1, 1, 0, 1, 1, 0, 1),
    "distributed":   (1, 0, 0, 0, 1, 1, 0, 0, 0),
}

_CAP_FIELDS = ("static", "batched", "streaming", "deletions", "sharded",
               "device_loop", "bit_exact_counters", "spanning_forest",
               "maintained_forest")


def test_public_api_surface_is_stable():
    assert sorted(api_mod.__all__) == EXPECTED_ALL
    assert available_backends() == EXPECTED_BACKENDS
    assert __version__                      # from repro import Solver works
    import repro
    assert repro.Solver is Solver
    assert sorted(repro.__all__) == sorted(["__version__"] + EXPECTED_ALL)


def test_backend_capability_matrix_is_stable():
    matrix = capability_matrix()
    assert sorted(matrix) == EXPECTED_BACKENDS
    got = {name: tuple(int(caps[f]) for f in _CAP_FIELDS)
           for name, caps in matrix.items()}
    assert got == EXPECTED_CAPABILITIES


def test_register_backend_is_a_one_decorator_change():
    """Third-party backends plug in with one decorator and are
    immediately routable by name (and duplicate names are rejected)."""
    name = "_test_constant"
    try:
        @register_backend(name, Capabilities(static=True))
        def _run(plan):
            import jax.numpy as jnp
            from repro.api import CCResult, WorkCounters
            return CCResult(jnp.zeros((plan.num_nodes,), jnp.int32),
                            WorkCounters.zeros())

        assert get_backend(name).capabilities.static
        res = Solver.open([[0, 1]], 3).solve(backend=name)
        assert np.asarray(res.labels).tolist() == [0, 0, 0]
        with pytest.raises(ValueError, match="already registered"):
            register_backend(name, Capabilities())(lambda plan: None)
    finally:
        BACKENDS.pop(name, None)

    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("_no_such_backend")


# ---------------------------------------------------------------------------
# ExecutionPlan: the adaptive decision, inspectable (acceptance criterion)
# ---------------------------------------------------------------------------

def test_plan_explain_reports_the_policy_choice():
    """For corpus graphs spanning the heuristic's regimes, the plan's
    backend equals what ``policy.select_method`` chooses for the same
    (|V|, |E|) — with a shared empty cache so the autotune layer cannot
    diverge the comparison."""
    cache = policy.AutotuneCache()          # in-memory, empty
    checked = 0
    for name, n, edges in corpus():
        if n == 0 or len(edges) == 0:
            continue
        solver = Solver.open(edges, n, policy_cache=cache)
        plan = solver.plan()
        want = policy.select_method(n, len(edges), cache=cache)
        assert plan.backend == want, (name, plan.backend, want)
        assert plan.reason == "heuristic"
        text = plan.explain()
        assert plan.backend in text
        assert plan.bucket_key in text
        assert f"|V|={n}" in text and f"|E|={len(edges)}" in text
        checked += 1
    assert checked >= 3                     # the ISSUE's floor


def test_plan_reports_autotune_provenance():
    """A warm autotune cache overrides the heuristic AND the plan says
    so."""
    name, n, edges = next(c for c in corpus() if c[1] > 0 and len(c[2]))
    cache = policy.AutotuneCache()
    cache.record(n, len(edges), "labelprop", 1.0)   # fake measured winner
    plan = Solver.open(edges, n, policy_cache=cache).plan()
    assert plan.backend == "labelprop"
    assert plan.reason == "autotune"
    assert "autotune" in plan.explain()


def test_plan_forced_backend_round_trips():
    """A forced backend override survives plan -> run -> result, for
    every static single-graph backend."""
    name, n, edges = next(c for c in corpus()
                          if c[1] > 0 and len(c[2]) >= 8)
    want = connected_components_oracle(edges, n)
    solver = Solver.open(edges, n)
    for backend in ("soman", "adaptive", "labelprop", "pallas_fused"):
        plan = solver.plan(backend=backend)
        assert plan.backend == backend and plan.reason == "forced"
        assert "forced" in plan.explain()
        res = plan.run()
        np.testing.assert_array_equal(np.asarray(res.labels), want,
                                      err_msg=backend)
        assert solver.plan(method=backend).backend == backend
    with pytest.raises(ValueError, match="unknown method"):
        solver.plan(method="frobnicate")
    # forced backends validate at PLAN time, not deep inside run()
    with pytest.raises(KeyError, match="unknown backend"):
        solver.plan(backend="_no_such")
    with pytest.raises(ValueError, match="solve_batch"):
        solver.plan(backend="batched")
    with pytest.raises(ValueError, match="needs a mesh"):
        solver.plan(backend="distributed")
    with pytest.raises(ValueError, match="not\\s+both"):
        solver.plan("soman", backend="adaptive")
    # typo'd tuning kwargs raise (legacy TypeError strictness kept)
    with pytest.raises(TypeError, match="unknown option"):
        solver.plan("adaptive", lift_step=9)
    # fresh sessions read zeroed counters, never KeyError
    assert Solver.open(num_nodes=3).work["hook_ops"] == 0


def test_plan_forced_method_wins_over_mesh_default():
    """A mesh session defaults to the distributed backend, but an
    explicitly named method must still route to its own backend (with
    real work counters), and unknown methods must still raise."""
    import jax
    name, n, edges = next(c for c in corpus()
                          if c[1] > 0 and len(c[2]) >= 8)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    solver = Solver.open(edges, n, mesh=mesh)
    assert solver.plan().backend == "distributed"
    plan = solver.plan("soman")
    assert plan.backend == "soman" and plan.reason == "forced"
    res = plan.run()
    assert int(res.work.hook_ops) > 0          # real counters, not zeros
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  connected_components_oracle(edges, n))
    with pytest.raises(ValueError, match="unknown method"):
        solver.plan("frobnicate")


def test_plan_segmentation_override_and_prediction():
    name, n, edges = next(c for c in corpus()
                          if c[1] > 0 and len(c[2]) >= 16)
    solver = Solver.open(edges, n)
    plan = solver.plan(method="adaptive", num_segments=4)
    assert plan.segmentation.num_segments == 4
    assert "override" in plan.explain()
    assert plan.predicted["hook_ops_per_round"] == len(edges)
    assert plan.predicted["jump_ops_per_sweep"] == n
    res = plan.run()
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  connected_components_oracle(edges, n))


def test_plan_on_mutated_session_uses_live_edge_features():
    """A streaming session's plan must feed the policy the host-tracked
    edge count, NOT the log's pow2 capacity padding — selection,
    autotune bucket, and explain() metadata all key off it."""
    from repro.core.batch import bucket_shape
    rng = np.random.default_rng(7)
    n = 64
    s = Solver.open(num_nodes=n, policy_cache=policy.AutotuneCache())
    edges = rng.integers(0, n, (1000, 2)).astype(np.int32)
    s.insert(edges)
    plan = s.plan()
    assert plan.num_edges == s.num_edges == 1000   # not capacity (1024+)
    assert plan.bucket == bucket_shape(n, 1000)
    want = policy.select_method(n, 1000, cache=s.policy_cache)
    assert plan.backend == want
    np.testing.assert_array_equal(
        np.asarray(plan.run().labels),
        connected_components_oracle(edges, n))


def test_plan_is_pure_host_metadata():
    """Planning never touches the device: legal in full under
    ``transfer_guard("disallow")`` once the graph is device-resident."""
    import jax
    from repro.graphs.device import DeviceGraph
    name, n, edges = next(c for c in corpus() if c[1] > 0 and len(c[2]))
    dg = DeviceGraph.from_edges(edges, n)
    solver = Solver.open(dg)
    with jax.transfer_guard("disallow"):
        plan = solver.plan()
        plan.explain()
        solver.plan(backend="pallas_fused").explain()


# ---------------------------------------------------------------------------
# The Solver session: solve + insert + delete + queries vs the oracle
# ---------------------------------------------------------------------------

def test_solver_session_full_lifecycle_matches_oracle():
    rng = np.random.default_rng(0)
    n = 32
    e1 = rng.integers(0, n, (40, 2)).astype(np.int32)
    e2 = rng.integers(0, n, (6, 2)).astype(np.int32)
    s = Solver.open(e1, n)
    oracle = DynamicConnectivityOracle(n)
    oracle.insert(e1)

    np.testing.assert_array_equal(np.asarray(s.solve().labels),
                                  oracle.labels())
    s.insert(e2)
    oracle.insert(e2)
    np.testing.assert_array_equal(np.asarray(s.labels), oracle.labels())

    kills = e1[:5]
    s.delete(kills)
    oracle.delete(kills)
    labels = oracle.labels()
    np.testing.assert_array_equal(np.asarray(s.labels), labels)

    # every queries.py lookup, via the session
    pairs = rng.integers(0, n, (17, 2))
    np.testing.assert_array_equal(
        s.same_component(pairs),
        labels[pairs[:, 0]] == labels[pairs[:, 1]])
    verts = rng.integers(0, n, 9)
    sizes = {v: int((labels == labels[v]).sum()) for v in verts}
    np.testing.assert_array_equal(
        s.component_size(verts), [sizes[v] for v in verts])
    assert s.num_components() == np.unique(labels).size
    assert s.connected(int(pairs[0, 0]), int(pairs[0, 1])) == bool(
        labels[pairs[0, 0]] == labels[pairs[0, 1]])
    hist = s.component_histogram()
    assert int(hist.sum()) == np.unique(labels).size
    np.testing.assert_array_equal(
        np.asarray(s.component_sizes()),
        [int((labels == c).sum()) for c in labels])

    # bounds validation at the facade boundary
    with pytest.raises(ValueError, match="out of range"):
        s.same_component([[0, n]])
    with pytest.raises(ValueError, match="out of range"):
        s.insert([[0, n]])
    with pytest.raises(ValueError, match="num_nodes"):
        from repro.graphs.device import DeviceGraph
        s.insert(DeviceGraph.from_edges([[0, 1]], n + 1))


def test_solver_open_requires_a_graph_or_num_nodes():
    with pytest.raises(ValueError, match="graph or"):
        Solver.open()
    # bare session over |V| only: labels solve lazily to identity —
    # and the property read leaves introspection state untouched
    s = Solver.open(num_nodes=5)
    assert np.asarray(s.labels).tolist() == [0, 1, 2, 3, 4]
    assert s.num_components() == 5
    assert s.stats["solves"] == 0
    assert s.last_method is None and s.last_plan is None


def test_solver_routes_mutations_through_policy():
    """Bulk first batch -> static rebuild; small second batch ->
    incremental absorb; small delete -> scoped tombstone route. Same
    contract the registry/service stack inherits from the facade."""
    g = np.stack([np.arange(30), np.arange(30) + 1], 1).astype(np.int32)
    s = Solver.open(num_nodes=31)
    s.insert(g)                              # bulk: no absorbed set yet
    assert s.last_method in policy.STATIC_METHODS + ("pallas_fused",)
    assert s.stats["rebuilds"] == 1
    s.insert(g[:3])
    assert s.last_method == policy.INCREMENTAL_ABSORB
    assert s.stats["absorbs"] == 1
    s.delete(g[:2])
    assert s.last_method in policy.DELETE_METHODS
    assert s.stats["scoped_deletes"] == 1
    assert s.version == int(s.version_device)
    # route counters stay internally consistent: every mutation is
    # classified exactly once
    assert s.stats["absorbs"] + s.stats["scoped_deletes"] + \
        s.stats["rebuilds"] == s.stats["inserts"] + s.stats["deletes"]

    # opening WITH edges counts the seed snapshot as the first (bulk)
    # insert, so the same invariant holds for graph-opened sessions
    s2 = Solver.open(g, 31)
    s2.insert(g[:3])
    assert s2.stats["inserts"] == 2          # seed + explicit batch
    assert s2.stats["absorbs"] + s2.stats["rebuilds"] == 2


def test_solver_steady_state_mutations_are_transfer_free():
    """Acceptance (ISSUE 5): the steady-state insert AND delete paths
    stay transfer-free under ``jax.transfer_guard("disallow")`` when
    driven directly via the facade (the service test pins the same
    property through the full registry/service stack)."""
    import jax
    from repro.graphs.device import DeviceGraph

    rng = np.random.default_rng(3)
    n = 64
    edges = rng.integers(0, n, (96, 2)).astype(np.int32)
    s = Solver.open(num_nodes=n)
    # warm every jit entry the steady state will hit
    s.insert(edges[:64])
    s.insert(DeviceGraph.from_edges(edges[64:72], n))
    s.delete(DeviceGraph.from_edges(edges[:8], n))

    with jax.transfer_guard("disallow"):
        s.insert(DeviceGraph.from_edges(edges[72:80], n))
        s.delete(DeviceGraph.from_edges(edges[8:16], n))

    oracle = DynamicConnectivityOracle(n)
    oracle.insert(edges[:80])
    oracle.delete(edges[:16])
    np.testing.assert_array_equal(np.asarray(s.labels), oracle.labels())


def test_solver_forest_route_steady_state_transfer_free():
    """ISSUE 9: the forest-maintaining absorb and the tree-aware delete
    are single-device-program ticks too — the steady state stays
    transfer-free under ``jax.transfer_guard("disallow")`` once warmed
    (the lazy ``ensure_forest`` rebuild is the only syncing exception
    and runs outside the guard here)."""
    import jax
    from repro.graphs.device import DeviceGraph

    rng = np.random.default_rng(5)
    n = 64
    edges = rng.integers(0, n, (96, 2)).astype(np.int32)
    s = Solver.open(num_nodes=n, delete_route="tombstone-delete-forest")
    s.insert(edges[:64])                 # bulk seed (may adopt)
    s.state.ensure_forest()              # repair + warm outside the guard
    s.insert(DeviceGraph.from_edges(edges[64:72], n))
    s.delete(DeviceGraph.from_edges(edges[:8], n))
    assert s.state.forest_valid

    with jax.transfer_guard("disallow"):
        s.insert(DeviceGraph.from_edges(edges[72:80], n))
        s.delete(DeviceGraph.from_edges(edges[8:16], n))

    oracle = DynamicConnectivityOracle(n)
    oracle.insert(edges[:80])
    oracle.delete(edges[:16])
    np.testing.assert_array_equal(np.asarray(s.labels), oracle.labels())


def test_solver_solve_batch_mixed_inputs():
    graphs = [(np.array([[0, 1], [2, 3]], np.int32), 5),
              (np.array([[0, 1]], np.int32), 2),
              (np.array([[1, 2], [0, 3], [3, 4]], np.int32), 6)]
    out = Solver.solve_batch(graphs)
    for (edges, n), res in zip(graphs, out):
        np.testing.assert_array_equal(
            np.asarray(res.labels),
            connected_components_oracle(edges, n))


# ---------------------------------------------------------------------------
# Single counting implementation (satellite): everything delegates to
# connectivity.queries.count_components
# ---------------------------------------------------------------------------

def test_num_components_single_implementation():
    from repro.connectivity import queries
    from repro.core.cc import num_components
    from repro.core.incremental import IncrementalCC

    for name, n, edges in corpus():
        if n == 0:
            continue
        labels = connected_components_oracle(edges, n)
        want = int(np.unique(labels).size)
        assert num_components(labels) == want, name
        assert int(queries.count_components(labels)) == want, name
        inc = IncrementalCC(n)
        inc.insert(edges)
        assert inc.num_components() == want, name
        s = Solver.open(edges, n)
        assert s.num_components() == want, name


def test_count_components_is_the_only_device_counter(monkeypatch):
    """Pin the delegation: cc.num_components, IncrementalCC, and the
    Solver all route through queries.count_components (monkeypatching
    it changes every answer)."""
    from repro.connectivity import queries
    from repro.core.cc import num_components
    from repro.core.incremental import IncrementalCC
    import jax.numpy as jnp

    monkeypatch.setattr(queries, "count_components",
                        lambda labels: jnp.asarray(12345, jnp.int32))
    labels = np.zeros(4, np.int32)
    assert num_components(labels) == 12345
    inc = IncrementalCC(4)
    assert inc.num_components() == 12345
    assert Solver.open(np.zeros((0, 2), np.int32), 4) \
        .num_components() == 12345


# ---------------------------------------------------------------------------
# Registry/service parity: the tenant layer is a thin shell over Solver
# ---------------------------------------------------------------------------

def test_tenant_graph_is_backed_by_a_solver_session():
    from repro.connectivity.registry import GraphRegistry

    reg = GraphRegistry()
    t = reg.create("t", 16)
    assert isinstance(t.solver, Solver)
    edges = np.array([[0, 1], [1, 2], [4, 5]], np.int32)
    reg.insert("t", edges)
    assert t.last_method == t.solver.last_method
    np.testing.assert_array_equal(np.asarray(t.labels),
                                  np.asarray(t.solver.labels))
    assert reg.count_components("t") == Solver.open(edges, 16) \
        .num_components()
