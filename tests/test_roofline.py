"""Roofline machinery: trip-count-aware HLO cost model + collective
parsing, validated on controlled compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import collective_stats, memory_summary
from repro.roofline.hlo_cost import analyze_hlo, parse_computations


def test_scan_matmul_flops_exact():
    n, L = 128, 8

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=L)
        return out

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    hc = analyze_hlo(comp.as_text())
    assert hc.flops == pytest.approx(L * 2 * n ** 3, rel=0.01)
    assert any(t == L for _, t in hc.loops)


def test_nested_scan_multiplies():
    n, Lo, Li = 64, 3, 5

    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=Li)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=Lo)
        return out

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    hc = analyze_hlo(comp.as_text())
    assert hc.flops == pytest.approx(Lo * Li * 2 * n ** 3, rel=0.01)


def test_hbm_bytes_lower_bound():
    n = 256

    def f(a, b):
        return a @ b

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
    hc = analyze_hlo(comp.as_text())
    floor = 3 * n * n * 4          # read a, b; write out
    assert hc.hbm_bytes >= floor
    assert hc.hbm_bytes < 10 * floor


def test_collective_parsing_from_synthetic_hlo():
    hlo = """
HloModule test

ENTRY %main (p: f32[128,64]) -> f32[128,64] {
  %p = f32[128,64]{1,0} parameter(0)
  ROOT %all-reduce = f32[128,64]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    stats = collective_stats(hlo)
    operand = 128 * 64 * 4
    assert stats.operand_bytes == operand
    assert stats.wire_bytes == pytest.approx(2 * operand * 3 / 4)
    assert stats.by_op["all-reduce"]["count"] == 1

    hc = analyze_hlo(hlo)
    assert hc.wire_bytes == pytest.approx(2 * operand * 3 / 4)


def test_parse_computation_structure():
    hlo = """
HloModule m

%body (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %t = f32[4]{0} tanh(%x)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} call(%a), to_apply=%body
}
"""
    comps, defs = parse_computations(hlo)
    assert set(comps) == {"body", "main"}
    assert defs["t"].startswith("f32[4]")


def test_memory_summary_fields():
    comp = jax.jit(lambda x: x * 2).lower(
        jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
    m = memory_summary(comp)
    assert "total_gib" in m
    assert m["argument_size_in_bytes"] == 4096
