"""LM transformer family: all five smoke configs, attention variants,
decode==forward consistency, loss equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving import engine as E

LM_ARCHS = ("qwen2.5-32b", "gemma2-2b", "minicpm3-4b", "grok-1-314b",
            "phi3.5-moe-42b-a6.6b")


@pytest.fixture(params=LM_ARCHS)
def smoke(request):
    cfg = get_arch(request.param).make_smoke_config()
    params = T.init(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def test_forward_shapes_and_finite(smoke, rng):
    name, cfg, params = smoke
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 16)), jnp.int32)
    logits, aux = T.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.slow
def test_loss_decreases_under_training(smoke, rng):
    from repro.train import loop
    from repro.train.optimizer import adamw, AdamWConfig
    name, cfg, params = smoke
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (4, 17)), jnp.int32)
    stream = iter(lambda: {"tokens": toks}, None)
    state, hist = loop.fit(
        loss_fn=lambda p, b: T.loss_fn(p, b, cfg), params=params,
        opt=adamw(AdamWConfig(lr=1e-3, weight_decay=0.0)),
        stream=stream, steps=30, log_every=30, log_fn=lambda s: None)
    first = float(T.loss_fn(params, {"tokens": toks}, cfg))
    last = float(T.loss_fn(state["params"], {"tokens": toks}, cfg))
    assert last < first, (name, first, last)


def test_chunked_loss_equals_dense(smoke, rng):
    name, cfg, params = smoke
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 13)), jnp.int32)
    logits, aux = T.forward(params, toks[:, :-1], cfg)
    dense = L.cross_entropy_loss(logits, toks[:, 1:]) + aux
    chunked = T.loss_fn(params, {"tokens": toks}, cfg, seq_chunk=5)
    assert abs(float(dense) - float(chunked)) < 1e-4, name


@pytest.mark.slow
def test_decode_matches_forward(smoke, rng):
    name, cfg, params = smoke
    B, S = 2, 12
    toks = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    full_logits, _ = T.forward(params, jnp.asarray(toks), cfg)
    full_next = np.asarray(jnp.argmax(full_logits, -1))
    lens = np.array([7, 12], np.int32)
    prompts = np.where(np.arange(S)[None] < lens[:, None], toks, -1)
    gen = E.generate(params, cfg, prompts, max_new=3, cache_buf=S + 8)
    assert gen[0, 0] == full_next[0, lens[0] - 1], name
    assert gen[1, 0] == full_next[1, lens[1] - 1], name
    # continuation consistency
    ext = np.concatenate([toks[:1, :lens[0]], gen[:1, :2]], 1)
    fl, _ = T.forward(params, jnp.asarray(ext), cfg)
    assert gen[0, 2] == np.asarray(jnp.argmax(fl, -1))[0, -1], name


@pytest.mark.slow
def test_blocked_attention_equals_dense(rng):
    B, S, H, D = 2, 2048, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    for window, cap in ((0, 0.0), (64, 0.0), (0, 25.0)):
        blk = L._attention_blocked(q, k, v, q_positions=pos,
                                   k_positions=pos, window=window,
                                   attn_softcap=cap, scale=D ** -0.5,
                                   kv_mask=None, block_k=256)
        dns = L._attention_dense(q, k, v, q_positions=pos,
                                 k_positions=pos, window=window,
                                 attn_softcap=cap, scale=D ** -0.5,
                                 kv_mask=None)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(dns),
                                   atol=3e-6)


def test_rope_batched_positions_consistent(rng):
    x = jnp.asarray(rng.standard_normal((2, 3, 4, 16)), jnp.float32)
    pos = jnp.asarray([5, 9, 11], jnp.int32)
    a = L.apply_rope(x, pos)
    b = L.apply_rope(x, jnp.broadcast_to(pos, (2, 3)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_param_counts_match_advertised():
    sizes = {"qwen2.5-32b": 32, "gemma2-2b": 2.6, "minicpm3-4b": 4,
             "grok-1-314b": 314, "phi3.5-moe-42b-a6.6b": 42}
    for name, want_b in sizes.items():
        cfg = get_arch(name).make_config()
        n = T.param_count(cfg)
        assert 0.7 * want_b < n / 1e9 < 1.35 * want_b, (name, n / 1e9)


def test_moe_aux_loss_nonzero(rng):
    cfg = get_arch("phi3.5-moe-42b-a6.6b").make_smoke_config()
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 16)), jnp.int32)
    _, aux = T.forward(params, toks, cfg)
    assert float(aux) > 0.0


def test_gemma_ties_embeddings():
    cfg = get_arch("gemma2-2b").make_config()
    assert cfg.tie_embed
    struct = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    assert "lm_head" not in struct


@pytest.mark.slow
def test_engine_continuous_batching_matches_standalone(rng):
    cfg = get_arch("qwen2.5-32b").make_smoke_config()
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = E.Engine(params, cfg, slots=2, prompt_buf=16, cache_buf=48)
    for _ in range(4):
        eng.submit(rng.integers(1, cfg.vocab,
                                int(rng.integers(3, 10))),
                   max_new=int(rng.integers(3, 7)))
    done = eng.run()
    assert len(done) == 4
    for r in done:
        prompts = np.full((1, 16), -1, np.int32)
        prompts[0, :len(r.prompt)] = r.prompt
        ref = E.generate(params, cfg, prompts,
                         max_new=len(r.out_tokens), cache_buf=48)
        np.testing.assert_array_equal(ref[0], np.array(r.out_tokens))
