"""Cross-mode differential conformance matrix (ISSUE 4).

Every execution mode of the stack must produce CANONICAL-LABEL-IDENTICAL
results over the shared ``_graphgen`` corpus:

  * the jnp single-graph variants (``soman | multijump | atomic_hook |
    adaptive | labelprop``),
  * the per-round Pallas backend (``connected_components_pallas``),
  * the fused Pallas backend (``method="pallas_fused"``),
  * the shape-bucketed batched engine,
  * an incremental (chunked insert) replay,
  * a fully-dynamic (insert + delete + re-insert) replay,
  * the 8-host-device distributed engine (subprocess — the main
    process must keep its single-device view),

all cross-checked against TWO independent host oracles (union-find and
scipy.sparse.csgraph) so an oracle bug cannot silently bless an engine
bug. Where bit-exactness of the WORK COUNTERS is claimed — the fused
backend against the jnp adaptive composition — the counters are
asserted equal field by field over the whole corpus, not just labels.

Also home of the ISSUE's counter-soundness property: accumulated
``WorkCounters`` totals are monotone non-decreasing across long
insert+delete sequences and never wrap int32 (pinning the PR-3 lazy
host-fold design: per-batch int32 device counters fold into host
arbitrary-precision ints).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from _graphgen import corpus, dynamic_scripts, edges_array
from _propcheck import given, settings, st
from repro.core.batch import connected_components_batched
from repro.core.cc import (METHODS, connected_components,
                           connected_components_pallas)
from repro.core.incremental import DynamicCC, IncrementalCC
from repro.core.rounds import WorkCounters
from repro.core.unionfind import (DynamicConnectivityOracle,
                                  connected_components_oracle,
                                  connected_components_scipy)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_SINGLE_METHODS = METHODS + ("pallas_fused",)


def oracle_labels(n, edges):
    """Union-find labels, cross-checked against the independent scipy
    oracle when available."""
    want = connected_components_oracle(edges, n)
    cross = connected_components_scipy(edges, n)
    if cross is not None:
        np.testing.assert_array_equal(want, cross,
                                      err_msg="oracles disagree")
    return want


# ---------------------------------------------------------------------------
# Static matrix: every single-graph mode, every corpus case
# ---------------------------------------------------------------------------

def test_conformance_single_graph_modes():
    for name, n, edges in corpus():
        want = oracle_labels(n, edges)
        for method in ALL_SINGLE_METHODS:
            got = connected_components(edges, n, method=method)
            np.testing.assert_array_equal(
                np.asarray(got.labels), want,
                err_msg=f"{name} method={method}")
        if n and len(edges):
            got = connected_components_pallas(edges, n, interpret=True)
            np.testing.assert_array_equal(np.asarray(got), want,
                                          err_msg=f"{name} pallas")


def test_conformance_batched_bit_identical():
    """ONE batched run over the whole corpus == per-graph adaptive,
    bit for bit, mixed shapes bucketed freely."""
    cases = [(name, n, e) for name, n, e in corpus() if n > 0]
    out = connected_components_batched([(e, n) for _, n, e in cases])
    for (name, n, edges), res in zip(cases, out):
        single = connected_components(edges, n, method="adaptive")
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      np.asarray(single.labels),
                                      err_msg=name)


def test_conformance_incremental_replay():
    """Chunked insert replay lands on the same canonical fixed point
    as every static mode."""
    for name, n, edges in corpus():
        inc = IncrementalCC(n)
        for chunk in np.array_split(edges, 3) if len(edges) else [edges]:
            inc.insert(chunk)
        np.testing.assert_array_equal(np.asarray(inc.labels),
                                      oracle_labels(n, edges),
                                      err_msg=name)


def test_conformance_dynamic_replay():
    """Insert everything, delete half, re-insert the deleted half: the
    dynamic engine must land back on the static fixed point — deletion
    plus re-insertion is an identity on the partition (not on the work
    done). Both scoped-scan backends."""
    for scan_method in ("jnp", "pallas_fused"):
        for name, n, edges in corpus():
            if n == 0:
                continue
            dyn = DynamicCC(n, scan_method=scan_method)
            oracle = DynamicConnectivityOracle(n)
            dyn.insert(edges)
            oracle.insert(edges)
            half = edges[: len(edges) // 2]
            dyn.delete(half)        # retires every copy, both orders
            oracle.delete(half)
            np.testing.assert_array_equal(
                np.asarray(dyn.labels), oracle.labels(),
                err_msg=f"{name} after delete ({scan_method})")
            dyn.insert(half)
            oracle.insert(half)
            np.testing.assert_array_equal(
                np.asarray(dyn.labels), oracle.labels(),
                err_msg=f"{name} after re-insert ({scan_method})")
            # ...and re-insertion restores the original partition
            np.testing.assert_array_equal(
                np.unique(np.asarray(dyn.labels)),
                np.unique(oracle_labels(n, edges)),
                err_msg=f"{name} partition ({scan_method})")


def test_conformance_work_counters_where_bit_exact_claimed():
    """The fused Pallas backend claims WorkCounters bit-compatibility
    with the jnp adaptive composition — hold it to that over the whole
    corpus, field by field."""
    for name, n, edges in corpus():
        a = connected_components(edges, n, method="adaptive")
        b = connected_components(edges, n, method="pallas_fused")
        np.testing.assert_array_equal(np.asarray(a.labels),
                                      np.asarray(b.labels), err_msg=name)
        for field, x, y in zip(WorkCounters._fields, a.work, b.work):
            assert int(x) == int(y), (name, field, int(x), int(y))


# ---------------------------------------------------------------------------
# Delete path vs oracle under interleaved scripts, differentially
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(dynamic_scripts(max_n=14, max_ops=6))
def test_conformance_dynamic_scripts_cross_mode(case):
    """After ANY interleaved insert/delete script: the dynamic engine,
    a from-scratch run of every static mode over the survivors, and
    the union-find/scipy oracles all agree on the labels."""
    n, script = case
    dyn = DynamicCC(n)
    oracle = DynamicConnectivityOracle(n)
    for op, batch in script:
        edges = edges_array(batch)
        (dyn.insert if op == 0 else dyn.delete)(edges)
        (oracle.insert if op == 0 else oracle.delete)(edges)
    want = oracle.labels()
    np.testing.assert_array_equal(np.asarray(dyn.labels), want,
                                  err_msg=str(script))
    survivors = edges_array(oracle.alive())
    for method in ("adaptive", "atomic_hook", "pallas_fused"):
        got = connected_components(survivors, n, method=method)
        np.testing.assert_array_equal(np.asarray(got.labels), want,
                                      err_msg=f"{method} {script}")


# ---------------------------------------------------------------------------
# 8-host-device distributed engine (subprocess keeps main single-device)
# ---------------------------------------------------------------------------

def test_conformance_distributed_8dev():
    """The sharded engine joins the matrix: same canonical labels as
    the oracle over the non-degenerate corpus, on 8 forced host
    devices, including edge counts that do not divide into 8."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from _graphgen import corpus
        from repro.core.distributed import make_distributed_cc
        from repro.core.unionfind import connected_components_oracle
        from repro.graphs.device import DeviceGraph
        assert len(jax.devices()) == 8
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        ran = 0
        for name, n, edges in corpus():
            if n == 0 or len(edges) < 8:
                continue
            dg = DeviceGraph.from_edges(edges, n).shard(mesh, ("data",))
            fn = make_distributed_cc(dg, mesh, ("data",))
            got = np.asarray(fn(dg))
            want = connected_components_oracle(edges, n)
            np.testing.assert_array_equal(got, want, err_msg=name)
            ran += 1
        assert ran >= 8, ran
        print("DIST_CONFORMANCE_OK", ran)
    """)
    # inherit the parent env (a stripped env stalls XLA's CPU client;
    # see test_distributed.run_sub) + put tests/ on the path for
    # _graphgen
    env = {**os.environ,
           "PYTHONPATH": "src" + os.pathsep + "tests"}
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env=env, cwd=_REPO_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DIST_CONFORMANCE_OK" in out.stdout


# ---------------------------------------------------------------------------
# WorkCounters soundness (ISSUE 4 satellite): monotone, no int32 wrap
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(dynamic_scripts(max_n=10, max_ops=8))
def test_work_counters_monotone_over_dynamic_sequences(case):
    """Accumulated totals never decrease across a long interleaved
    insert+delete sequence — every counter is a cost, and costs only
    accrue."""
    n, script = case
    dyn = DynamicCC(n)
    prev = dict(dyn.work)
    for op, batch in script:
        (dyn.insert if op == 0 else dyn.delete)(edges_array(batch))
        now = dyn.work
        for field in WorkCounters._fields:
            assert now[field] >= prev[field], (field, prev, now)
        assert all(v >= 0 for v in now.values()), now
        prev = now


def test_work_counters_never_wrap_int32():
    """Pin the PR-3 lazy host-fold design: per-batch counters are int32
    DEVICE scalars (cheap, unsynced), but they fold into host
    arbitrary-precision ints — so accumulated totals sail past
    2**31 - 1 without wrapping, including through the amortized
    auto-drain every ``_DRAIN_EVERY`` pending batches."""
    import jax.numpy as jnp
    from repro.core import incremental as inc_mod

    inc = IncrementalCC(4)
    big = 1 << 30                           # fits int32; 4x overflows it
    batch = WorkCounters(*(jnp.full((), big, jnp.int32)
                           for _ in WorkCounters._fields))
    n_batches = inc_mod._DRAIN_EVERY + 10   # forces >= 1 amortized drain
    for _ in range(n_batches):
        inc._queue_work(batch)
    # the amortized drain fired mid-stream (lazy fold, not unbounded
    # device-counter accumulation)
    assert len(inc._work_pending) == 10
    totals = inc.work
    want = big * n_batches
    assert want > 2**31 - 1                 # the wrap hazard is real
    for field, value in totals.items():
        assert value == want, (field, value, want)
