"""Cross-mode differential conformance matrix (ISSUE 4 + ISSUE 5).

Every execution mode of the stack must produce CANONICAL-LABEL-IDENTICAL
results over the shared ``_graphgen`` corpus — and, since ISSUE 5,
every mode is invoked THROUGH the public facade
(``repro.api.Solver`` / the ``BACKENDS`` registry), so the matrix
pins the whole dispatch path, not just the engines:

  * the jnp single-graph backends (``soman | multijump | atomic_hook |
    adaptive | labelprop``),
  * the per-round Pallas backend (``backend="pallas"``),
  * the fused Pallas backend (``backend="pallas_fused"``),
  * the shape-bucketed batched backend (``Solver.solve_batch``),
  * an incremental (chunked insert) replay through a ``Solver``
    streaming session,
  * a fully-dynamic (insert + delete + re-insert) replay through the
    same session API, both scoped-scan backends,
  * the 8-host-device distributed backend (subprocess — the main
    process must keep its single-device view),

all cross-checked against TWO independent host oracles (union-find and
scipy.sparse.csgraph) so an oracle bug cannot silently bless an engine
bug. Where bit-exactness of the WORK COUNTERS is claimed — the fused
backend against the jnp adaptive composition — the counters are
asserted equal field by field over the whole corpus, not just labels.

Also home of:
  * the SHIM column (ISSUE 5): every deprecated legacy entrypoint
    emits a ``DeprecationWarning`` exactly once per process and returns
    results bit-identical to its facade route;
  * the counter-soundness properties: accumulated ``WorkCounters``
    totals are monotone non-decreasing across long insert+delete
    sequences and never wrap int32 (the PR-3 lazy host-fold design).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np

from _graphgen import corpus, dynamic_scripts, edges_array
from _propcheck import given, settings, st
from repro import _deprecation
from repro.api import BACKENDS, Solver, solve
from repro.core.cc import METHODS
from repro.core.rounds import WorkCounters
from repro.core.unionfind import (DynamicConnectivityOracle,
                                  connected_components_oracle,
                                  connected_components_scipy)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_SINGLE_BACKENDS = METHODS + ("pallas_fused", "sampled",
                                 "sampled_fused")


def oracle_labels(n, edges):
    """Union-find labels, cross-checked against the independent scipy
    oracle when available."""
    want = connected_components_oracle(edges, n)
    cross = connected_components_scipy(edges, n)
    if cross is not None:
        np.testing.assert_array_equal(want, cross,
                                      err_msg="oracles disagree")
    return want


# ---------------------------------------------------------------------------
# Static matrix: every single-graph backend, every corpus case, via Solver
# ---------------------------------------------------------------------------

def test_conformance_single_graph_backends_via_solver():
    for name, n, edges in corpus():
        want = oracle_labels(n, edges)
        solver = Solver.open(edges, n)
        for backend in ALL_SINGLE_BACKENDS:
            assert backend in BACKENDS, backend
            got = solver.solve(backend=backend)
            np.testing.assert_array_equal(
                np.asarray(got.labels), want,
                err_msg=f"{name} backend={backend}")
        if n and len(edges):
            got = solver.solve(backend="pallas", interpret=True)
            np.testing.assert_array_equal(np.asarray(got.labels), want,
                                          err_msg=f"{name} pallas")


def test_conformance_auto_routes_to_a_registered_backend():
    """method="auto" must land on a registry entry and agree with the
    oracle — whatever the policy picks."""
    for name, n, edges in corpus():
        solver = Solver.open(edges, n)
        plan = solver.plan()
        assert plan.backend in BACKENDS, (name, plan.backend)
        got = solver.solve()
        np.testing.assert_array_equal(np.asarray(got.labels),
                                      oracle_labels(n, edges),
                                      err_msg=f"{name} auto={plan.backend}")


def test_conformance_batched_bit_identical():
    """ONE Solver.solve_batch over the whole corpus == per-graph
    adaptive solves, bit for bit, mixed shapes bucketed freely."""
    cases = [(name, n, e) for name, n, e in corpus() if n > 0]
    out = Solver.solve_batch([(e, n) for _, n, e in cases])
    for (name, n, edges), res in zip(cases, out):
        single = solve(edges, n, method="adaptive")
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      np.asarray(single.labels),
                                      err_msg=name)


def test_conformance_incremental_replay_via_solver():
    """Chunked insert replay through a facade streaming session lands
    on the same canonical fixed point as every static mode."""
    for name, n, edges in corpus():
        s = Solver.open(num_nodes=n)
        for chunk in np.array_split(edges, 3) if len(edges) else [edges]:
            s.insert(chunk)
        np.testing.assert_array_equal(np.asarray(s.labels),
                                      oracle_labels(n, edges),
                                      err_msg=name)


def test_conformance_dynamic_replay_via_solver():
    """Insert everything, delete half, re-insert the deleted half: the
    facade session must land back on the static fixed point — deletion
    plus re-insertion is an identity on the partition (not on the work
    done). Both scoped-scan backends, forced via ``scan_method``."""
    for scan_method in ("jnp", "pallas_fused"):
        for name, n, edges in corpus():
            if n == 0:
                continue
            s = Solver.open(num_nodes=n, scan_method=scan_method)
            oracle = DynamicConnectivityOracle(n)
            s.insert(edges)
            oracle.insert(edges)
            half = edges[: len(edges) // 2]
            s.delete(half)          # retires every copy, both orders
            oracle.delete(half)
            np.testing.assert_array_equal(
                np.asarray(s.labels), oracle.labels(),
                err_msg=f"{name} after delete ({scan_method})")
            s.insert(half)
            oracle.insert(half)
            np.testing.assert_array_equal(
                np.asarray(s.labels), oracle.labels(),
                err_msg=f"{name} after re-insert ({scan_method})")
            # ...and re-insertion restores the original partition
            np.testing.assert_array_equal(
                np.unique(np.asarray(s.labels)),
                np.unique(oracle_labels(n, edges)),
                err_msg=f"{name} partition ({scan_method})")


def test_conformance_work_counters_where_bit_exact_claimed():
    """The fused Pallas backend claims WorkCounters bit-compatibility
    with the jnp adaptive composition — hold it to that over the whole
    corpus, field by field, through the facade."""
    assert BACKENDS["pallas_fused"].capabilities.bit_exact_counters
    for name, n, edges in corpus():
        a = solve(edges, n, backend="adaptive")
        b = solve(edges, n, backend="pallas_fused")
        np.testing.assert_array_equal(np.asarray(a.labels),
                                      np.asarray(b.labels), err_msg=name)
        for field, x, y in zip(WorkCounters._fields, a.work, b.work):
            assert int(x) == int(y), (name, field, int(x), int(y))


# ---------------------------------------------------------------------------
# Spanning forest (ISSUE 8): acyclic, one root per component, spans it
# ---------------------------------------------------------------------------

def _assert_valid_forest(tag, n, labels, parents):
    """The full forest property, host-side: the recorded parent edges
    are acyclic (every union merges two distinct sets), exactly
    |V| - C of them, roots are the component minima, and the forest's
    partition equals the labels' partition."""
    valid = parents[:, 0] >= 0
    ncomp = len(np.unique(labels)) if n else 0
    assert int(valid.sum()) == n - ncomp, (tag, int(valid.sum()),
                                           n - ncomp)
    pa = list(range(n))

    def find(x):
        while pa[x] != x:
            pa[x] = pa[pa[x]]
            x = pa[x]
        return x

    for u, v in parents[valid]:
        assert labels[u] == labels[v], (tag, "cross-component edge")
        ru, rv = find(int(u)), find(int(v))
        assert ru != rv, (tag, "cycle in recorded forest")
        pa[ru] = rv
    # partition equality: forest components == label components
    for i in range(n):
        assert find(i) == find(int(labels[i])), (tag, i)
    # one root per component, and it is the component minimum
    roots = np.flatnonzero(~valid)
    assert len(roots) == ncomp, tag
    np.testing.assert_array_equal(np.sort(labels[roots]),
                                  np.unique(labels), err_msg=tag)


def test_conformance_spanning_forest_property():
    """Every forest-recording method, every corpus case: canonical
    labels identical to the oracles AND the recorded parent edges form
    a valid spanning forest. The on-device validation kernel
    (``queries.spanning_forest_stats``) must agree with the host-side
    proof."""
    from repro.connectivity.queries import spanning_forest_stats
    from repro.core.cc import FOREST_METHODS, solve_forest
    for name, n, edges in corpus():
        want = oracle_labels(n, edges)
        for method in FOREST_METHODS:
            res = solve_forest(edges, n, method=method)
            labels = np.asarray(res.labels)
            parents = np.asarray(res.parents)
            np.testing.assert_array_equal(
                labels, want, err_msg=f"{name} forest method={method}")
            _assert_valid_forest(f"{name}/{method}", n, labels, parents)
            stats = spanning_forest_stats(res.labels, res.parents)
            assert bool(stats["edges_intra_component"]), (name, method)
            assert bool(stats["count_consistent"]), (name, method)


def test_spanning_forest_via_solver_facade():
    """``Solver.spanning_forest()``: same labels as ``solve()``, a
    valid forest, cached until a mutation invalidates it, and refused
    for non-recording backends."""
    import pytest

    name, n, edges = next(c for c in corpus()
                          if c[1] > 4 and len(c[2]) > 4)
    s = Solver.open(edges, n)
    res = s.spanning_forest()
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  oracle_labels(n, edges))
    _assert_valid_forest("facade", n, np.asarray(res.labels),
                         np.asarray(res.parents))
    assert s.spanning_forest() is res          # cached
    with pytest.raises(ValueError, match="does not record"):
        s.spanning_forest(method="labelprop")

    # mutation invalidates: the forest re-derives over the new edge set
    s2 = Solver.open(num_nodes=6)
    s2.insert([[0, 1]])
    f1 = s2.spanning_forest()
    assert int((np.asarray(f1.parents)[:, 0] >= 0).sum()) == 1
    s2.insert([[2, 3], [1, 2]])
    f2 = s2.spanning_forest()
    assert f2 is not f1
    assert int((np.asarray(f2.parents)[:, 0] >= 0).sum()) == 3
    _assert_valid_forest("mutated", 6, np.asarray(f2.labels),
                         np.asarray(f2.parents))

    # ISSUE 9 satellite: the cache is keyed on the label VERSION, not
    # on mutation count — an insert whose absorb provably merged
    # nothing (version unticked) keeps the cached object alive...
    v = int(s2.version)
    s2.insert([[0, 1]])                     # redundant: merges nothing
    assert int(s2.version) == v
    assert s2.spanning_forest() is f2       # cache survives the insert
    # ...while a merging insert ticks the version and re-derives
    s2.insert([[4, 5]])
    assert int(s2.version) == v + 1
    f3 = s2.spanning_forest()
    assert f3 is not f2
    # and delete() always invalidates, version tick or not
    s2.delete([[4, 5]])
    assert s2.spanning_forest() is not f3


# ---------------------------------------------------------------------------
# Shim column (ISSUE 5): legacy entrypoints == facade, warn exactly once
# ---------------------------------------------------------------------------

def _deprecation_count(record):
    return sum(1 for w in record
               if issubclass(w.category, DeprecationWarning))


def test_shims_bit_identical_and_warn_exactly_once():
    """Every legacy entrypoint forwards into the facade: results are
    bit-identical to the facade route, and each emits exactly ONE
    ``DeprecationWarning`` per process (first call warns, repeat calls
    stay silent)."""
    from repro.core.batch import connected_components_batched
    from repro.core.cc import (connected_components,
                               connected_components_hostloop,
                               connected_components_pallas)

    cases = [(name, n, e) for name, n, e in corpus()
             if n > 0 and len(e) > 0][:4]
    _deprecation.reset()

    for name, n, edges in cases:
        facade = solve(edges, n, method="adaptive")
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            legacy = connected_components(edges, n, method="adaptive")
        np.testing.assert_array_equal(np.asarray(legacy.labels),
                                      np.asarray(facade.labels),
                                      err_msg=name)
        for f, x, y in zip(WorkCounters._fields, legacy.work,
                           facade.work):
            assert int(x) == int(y), (name, f)

        fp = Solver.open(edges, n).solve(backend="pallas",
                                         interpret=True)
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            lp = connected_components_pallas(edges, n, interpret=True)
        np.testing.assert_array_equal(np.asarray(lp),
                                      np.asarray(fp.labels),
                                      err_msg=name)

    # warn-exactly-once, per entrypoint: the calls above already warmed
    # the warn registry; fresh calls must be silent now
    name, n, edges = cases[0]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        connected_components(edges, n)
        connected_components_pallas(edges, n, interpret=True)
    assert _deprecation_count(rec) == 0, [str(w.message) for w in rec]

    # ...and after a reset, each warns once (and only once) again
    _deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        connected_components(edges, n)
        connected_components(edges, n)
    assert _deprecation_count(rec) == 1, [str(w.message) for w in rec]

    # hostloop shim: labels + stats identical to the facade plan route
    _deprecation.reset()
    plan = Solver.open(edges, n).plan(backend="hostloop",
                                      hostloop_method="soman")
    fres = plan.run()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        labels, stats = connected_components_hostloop(edges, n,
                                                      method="soman")
        connected_components_hostloop(edges, n, method="soman")
    assert _deprecation_count(rec) == 1
    np.testing.assert_array_equal(labels, np.asarray(fres.labels))
    assert stats == plan.artifacts["hostloop_stats"]

    # batched shim
    _deprecation.reset()
    fbatch = Solver.solve_batch([(e, n) for _, n, e in cases])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        lbatch = connected_components_batched(
            [(e, n) for _, n, e in cases])
        connected_components_batched([(e, n) for _, n, e in cases])
    assert _deprecation_count(rec) == 1
    for f, l in zip(fbatch, lbatch):
        np.testing.assert_array_equal(np.asarray(l.labels),
                                      np.asarray(f.labels))


def test_shim_distributed_single_device():
    """The distributed legacy entrypoints forward through the facade's
    ``distributed`` backend (single-device mesh in-process; the 8-device
    form is covered by the subprocess matrix row)."""
    import jax
    from repro.core.distributed import distributed_connected_components
    from repro.graphs.device import DeviceGraph

    name, n, edges = next((c for c in corpus() if c[1] > 0 and
                           len(c[2]) >= 8))
    dg = DeviceGraph.from_edges(edges, n)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    facade = Solver.open(dg, mesh=mesh).solve()
    _deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = distributed_connected_components(dg, mesh)
        distributed_connected_components(dg, mesh)
    assert _deprecation_count(rec) == 1
    np.testing.assert_array_equal(np.asarray(legacy),
                                  np.asarray(facade.labels))
    np.testing.assert_array_equal(np.asarray(legacy),
                                  oracle_labels(n, edges))


# ---------------------------------------------------------------------------
# Delete path vs oracle under interleaved scripts, differentially
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(dynamic_scripts(max_n=14, max_ops=6))
def test_conformance_dynamic_scripts_cross_mode(case):
    """After ANY interleaved insert/delete script through the facade
    session: the dynamic state, a from-scratch facade solve of every
    static mode over the survivors, and the union-find/scipy oracles
    all agree on the labels."""
    n, script = case
    s = Solver.open(num_nodes=n)
    oracle = DynamicConnectivityOracle(n)
    for op, batch in script:
        edges = edges_array(batch)
        (s.insert if op == 0 else s.delete)(edges)
        (oracle.insert if op == 0 else oracle.delete)(edges)
    want = oracle.labels()
    np.testing.assert_array_equal(np.asarray(s.labels), want,
                                  err_msg=str(script))
    survivors = edges_array(oracle.alive())
    for backend in ("adaptive", "atomic_hook", "pallas_fused"):
        got = solve(survivors, n, backend=backend)
        np.testing.assert_array_equal(np.asarray(got.labels), want,
                                      err_msg=f"{backend} {script}")


# ---------------------------------------------------------------------------
# Maintained forest + tree-aware deletes (ISSUE 9)
# ---------------------------------------------------------------------------

def _forest_pairs(dyn):
    """The maintained forest's edge set as normalized host tuples."""
    parents = np.asarray(dyn.forest[0])
    has = parents[:, 0] >= 0
    return {tuple(sorted(map(int, parents[r])))
            for r in np.flatnonzero(has)}


def _assert_maintained_forest(tag, s):
    """Full maintained-forest invariant: a valid spanning forest of the
    live labels (acyclic, exactly |V| - C edges, roots = component
    minima) AND every recorded ``parent_eidx`` points at an ALIVE log
    row holding that very edge (the compaction-permutation contract)."""
    dyn = s.state
    assert dyn.forest_valid, tag
    n = dyn.num_nodes
    labels = np.asarray(s.labels)
    parents = np.asarray(dyn.forest[0])
    parent_eidx = np.asarray(dyn.forest[1])
    _assert_valid_forest(tag, n, labels, parents)
    log_edges = np.asarray(dyn.log.edges)
    log_alive = np.asarray(dyn.log.alive)
    has = parents[:, 0] >= 0
    np.testing.assert_array_equal(parent_eidx[~has],
                                  np.full(int((~has).sum()), -1),
                                  err_msg=f"{tag}: root rows must be -1")
    for r in np.flatnonzero(has):
        eid = int(parent_eidx[r])
        assert 0 <= eid < dyn.log.rows, (tag, int(r), eid)
        assert bool(log_alive[eid]), (tag, int(r), eid, "dead log row")
        assert (sorted(map(int, log_edges[eid]))
                == sorted(map(int, parents[r]))), (tag, int(r), eid)


def test_maintained_forest_interleaved_scripts_vs_oracle():
    """ISSUE 9 conformance rows: three interleaved insert/delete
    scripts through the forced forest delete route — deletes hitting
    only NON-tree edges (short-circuit: labels, version and hook work
    untouched), only TREE edges (scoped reconnection), and a mixed
    batch. After EVERY step: labels canonically identical to the
    union-find oracle, version ticked iff the partition changed (i.e.
    iff a component actually split), and the maintained forest acyclic
    with exactly |V| - C alive parent edges."""
    n = 12
    ring = [[i, (i + 1) % n] for i in range(n)]
    chords = [[0, 6], [3, 9], [1, 4], [5, 8]]
    base = np.asarray(ring + chords, np.int32)
    alive0 = {tuple(sorted(map(int, e))) for e in base}

    def fresh():
        s = Solver.open(num_nodes=n,
                        delete_route="tombstone-delete-forest")
        oracle = DynamicConnectivityOracle(n)
        s.insert(base)
        oracle.insert(base)
        s.state.ensure_forest()     # the bulk insert may have adopted
        return s, oracle

    def step(s, oracle, op, batch, tag):
        batch = np.asarray(batch, np.int32).reshape(-1, 2)
        before = np.asarray(s.labels).copy()
        v0 = int(s.version)
        (s.insert if op == "ins" else s.delete)(batch)
        (oracle.insert if op == "ins" else oracle.delete)(batch)
        after = np.asarray(s.labels)
        np.testing.assert_array_equal(after, oracle.labels(),
                                      err_msg=tag)
        changed = not np.array_equal(before, after)
        assert (int(s.version) != v0) == changed, (tag, v0,
                                                   int(s.version))
        _assert_maintained_forest(tag, s)

    # -- script A: every delete hits only non-tree edges --------------
    s, oracle = fresh()
    non_tree = sorted(alive0 - _forest_pairs(s.state))
    assert len(non_tree) >= 5           # 16 edges, spanning tree is 11
    hook0 = s.work["hook_ops"]
    step(s, oracle, "del", non_tree[:2], "A1")
    step(s, oracle, "del", [non_tree[2]], "A2")
    # the short-circuit bills ZERO hook work for all-non-tree batches
    assert s.work["hook_ops"] == hook0
    rc = s.state.delete_route_counts()
    assert rc["nontree_shortcircuit"] == 2 and rc["tree_scoped"] == 0
    step(s, oracle, "ins", [[2, 7]], "A3")   # redundant: stays non-tree
    step(s, oracle, "del", [non_tree[3]], "A4")
    rc = s.state.delete_route_counts()
    assert rc["nontree_shortcircuit"] == 3 and rc["tree_scoped"] == 0

    # -- script B: every delete hits the live tree ---------------------
    s, oracle = fresh()
    for i in range(4):
        tree = sorted(_forest_pairs(s.state))
        step(s, oracle, "del", [tree[i % len(tree)]], f"B{i}")
    rc = s.state.delete_route_counts()
    assert rc["nontree_shortcircuit"] == 0 and rc["tree_scoped"] == 4

    # -- script C: mixed batches (tree + non-tree rows together) -------
    s, oracle = fresh()
    tree = sorted(_forest_pairs(s.state))
    non_tree = sorted(alive0 - set(tree))
    step(s, oracle, "del", [tree[0], non_tree[0]], "C1")
    step(s, oracle, "ins", [tree[0]], "C2")  # resurrect the tree edge
    tree2 = sorted(_forest_pairs(s.state))
    step(s, oracle, "del", [tree2[0], tree2[1], non_tree[1]], "C3")
    rc = s.state.delete_route_counts()
    assert rc["nontree_shortcircuit"] == 0 and rc["tree_scoped"] == 2


@settings(max_examples=6, deadline=None)
@given(dynamic_scripts(max_n=12, max_ops=6))
def test_maintained_forest_random_scripts(case):
    """Property form of the ISSUE 9 rows: ANY interleaved script on
    the forced forest route stays canonical-label-identical to the
    oracle, ticks the version iff the partition changed, and keeps the
    maintained forest valid after every step."""
    n, script = case
    s = Solver.open(num_nodes=n, delete_route="tombstone-delete-forest")
    oracle = DynamicConnectivityOracle(n)
    for op, batch in script:
        edges = edges_array(batch)
        before = np.asarray(s.labels).copy()
        v0 = int(s.version)
        (s.insert if op == 0 else s.delete)(edges)
        (oracle.insert if op == 0 else oracle.delete)(edges)
        after = np.asarray(s.labels)
        np.testing.assert_array_equal(after, oracle.labels(),
                                      err_msg=str(script))
        changed = not np.array_equal(before, after)
        assert (int(s.version) != v0) == changed, str(script)
        if s._dyn is not None and s.state.forest_valid:
            _assert_maintained_forest(str((op, batch)), s)


# ---------------------------------------------------------------------------
# 8-host-device distributed backend (subprocess keeps main single-device)
# ---------------------------------------------------------------------------

def test_conformance_distributed_8dev():
    """The sharded backend joins the matrix THROUGH the facade: same
    canonical labels as the oracle over the non-degenerate corpus, on 8
    forced host devices, including edge counts that do not divide into
    8."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from _graphgen import corpus
        from repro.api import Solver
        from repro.core.unionfind import connected_components_oracle
        assert len(jax.devices()) == 8
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
        ran = 0
        for name, n, edges in corpus():
            if n == 0 or len(edges) < 8:
                continue
            solver = Solver.open(edges, n, mesh=mesh)
            plan = solver.plan()
            assert plan.backend == "distributed", plan.backend
            assert plan.reason == "sharded", plan.reason
            got = np.asarray(solver.solve().labels)
            want = connected_components_oracle(edges, n)
            np.testing.assert_array_equal(got, want, err_msg=name)
            ran += 1
        assert ran >= 8, ran
        print("DIST_CONFORMANCE_OK", ran)
    """)
    # inherit the parent env (a stripped env stalls XLA's CPU client;
    # see test_distributed.run_sub) + put tests/ on the path for
    # _graphgen
    env = {**os.environ,
           "PYTHONPATH": "src" + os.pathsep + "tests"}
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env=env, cwd=_REPO_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DIST_CONFORMANCE_OK" in out.stdout


# ---------------------------------------------------------------------------
# WorkCounters soundness (ISSUE 4 satellite): monotone, no int32 wrap
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(dynamic_scripts(max_n=10, max_ops=8))
def test_work_counters_monotone_over_dynamic_sequences(case):
    """Accumulated totals never decrease across a long interleaved
    insert+delete sequence through the facade — every counter is a
    cost, and costs only accrue."""
    n, script = case
    s = Solver.open(num_nodes=n)
    prev = dict(s.work)                      # zeroed pre-mutation
    for op, batch in script:
        (s.insert if op == 0 else s.delete)(edges_array(batch))
        now = s.work
        for field in WorkCounters._fields:
            assert now[field] >= prev[field], (field, prev, now)
        assert all(v >= 0 for v in now.values()), now
        prev = now


def test_work_counters_never_wrap_int32():
    """Pin the PR-3 lazy host-fold design: per-batch counters are int32
    DEVICE scalars (cheap, unsynced), but they fold into host
    arbitrary-precision ints — so accumulated totals sail past
    2**31 - 1 without wrapping, including through the amortized
    auto-drain every ``_DRAIN_EVERY`` pending batches."""
    import jax.numpy as jnp
    from repro.core import incremental as inc_mod
    from repro.core.incremental import IncrementalCC

    inc = IncrementalCC(4)
    big = 1 << 30                           # fits int32; 4x overflows it
    batch = WorkCounters(*(jnp.full((), big, jnp.int32)
                           for _ in WorkCounters._fields))
    n_batches = inc_mod._DRAIN_EVERY + 10   # forces >= 1 amortized drain
    for _ in range(n_batches):
        inc._queue_work(batch)
    # the amortized drain fired mid-stream (lazy fold, not unbounded
    # device-counter accumulation)
    assert len(inc._work_pending) == 10
    totals = inc.work
    want = big * n_batches
    assert want > 2**31 - 1                 # the wrap hazard is real
    for field, value in totals.items():
        assert value == want, (field, value, want)
