"""Config registry: all 40 cells well-formed; smoke configs small."""
import math

import jax
import pytest

from repro.configs import ARCH_IDS, all_cells, get_arch


def test_registry_has_ten_archs_forty_cells():
    assert len(ARCH_IDS) == 10
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, r in cells if r]
    # exactly the pure-full-attention long_500k cells skip
    assert set(skipped) == {
        ("qwen2.5-32b", "long_500k"), ("minicpm3-4b", "long_500k"),
        ("grok-1-314b", "long_500k"),
        ("phi3.5-moe-42b-a6.6b", "long_500k")}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_are_structs(arch_id):
    mod = get_arch(arch_id)
    for shape in mod.SHAPES:
        if mod.skip_reason(shape):
            continue
        specs = mod.input_specs(shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, (arch_id, shape)
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                assert all(d > 0 for d in leaf.shape)
        assert mod.step_kind(shape) in ("train", "prefill", "decode",
                                        "serve", "retrieval")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_configs_are_small(arch_id):
    mod = get_arch(arch_id)
    cfg = mod.make_smoke_config()
    # a smoke config must instantiate in well under a GB
    if mod.FAMILY == "lm":
        from repro.models.transformer import param_count
        assert param_count(cfg) < 5e6, arch_id
    assert "smoke" in cfg.name


def test_assigned_dims_exact():
    """The exact architecture numbers from the assignment."""
    q = get_arch("qwen2.5-32b").make_config()
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab) == (64, 5120, 40, 8, 27648, 152064)
    assert q.qkv_bias
    g = get_arch("gemma2-2b").make_config()
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (26, 2304, 8, 4, 9216, 256000)
    assert g.layer_pattern == "local_global" and g.attn_softcap > 0
    m = get_arch("minicpm3-4b").make_config()
    assert (m.n_layers, m.d_model, m.n_heads, m.d_ff, m.vocab) == \
        (62, 2560, 40, 6400, 73448)
    assert m.attention == "mla"
    k = get_arch("grok-1-314b").make_config()
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads, k.vocab) == \
        (64, 6144, 48, 8, 131072)
    assert k.moe.num_experts == 8 and k.moe.top_k == 2
    p = get_arch("phi3.5-moe-42b-a6.6b").make_config()
    assert (p.n_layers, p.d_model, p.n_heads, p.vocab) == \
        (32, 4096, 32, 32064)
    assert p.moe.num_experts == 16 and p.moe.top_k == 2
    n = get_arch("nequip").make_config()
    assert (n.n_layers, n.d_hidden, n.l_max, n.n_rbf, n.cutoff) == \
        (5, 32, 2, 8, 5.0)
    gg = get_arch("gatedgcn").make_config()
    assert (gg.n_layers, gg.d_hidden) == (16, 70)
    sa = get_arch("graphsage-reddit").make_config()
    assert (sa.n_layers, sa.d_hidden) == (2, 128)
    gi = get_arch("gin-tu").make_config("molecule")
    assert (gi.n_layers, gi.d_hidden) == (5, 64)
    d = get_arch("dcn-v2").make_config()
    assert (d.n_dense, d.n_sparse, d.embed_dim, d.n_cross) == \
        (13, 26, 16, 3)
    assert d.mlp == (1024, 1024, 512)


def test_shape_sets_match_assignment():
    from repro.configs.lm_common import SHAPE_DEFS as LM
    assert LM["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert LM["prefill_32k"] == dict(kind="prefill", seq=32768, batch=32)
    assert LM["decode_32k"] == dict(kind="decode", seq=32768, batch=128)
    assert LM["long_500k"] == dict(kind="decode", seq=524288, batch=1)
    from repro.configs.dcn_v2 import SHAPE_DEFS as RS
    assert RS["train_batch"]["batch"] == 65536
    assert RS["serve_bulk"]["batch"] == 262144
    assert RS["retrieval_cand"]["candidates"] == 1_000_000


def test_mesh_construction_function_not_constant():
    import repro.launch.mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod)
    assert "def make_production_mesh" in src
    # importing the module must not have created a mesh
    assert not any(isinstance(v, jax.sharding.Mesh)
                   for v in vars(mesh_mod).values())


def test_dryrun_sets_xla_flags_first():
    path = "src/repro/launch/dryrun.py"
    with open(path) as f:
        lines = f.read().splitlines()
    assert lines[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in lines[1]
