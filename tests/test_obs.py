"""PR 7 acceptance: the runtime telemetry layer (``repro.obs``).

Pins the tentpole contracts: histogram quantile math against an
``np.percentile`` oracle, merge-associativity of the device ``Metrics``
pytree, ring-buffer wraparound, the no-op cost model of disabled
spans, plan-provenance tags (via the ``ExecutionPlan.as_dict()``
schema snapshot), the always-on autotune/deprecation counters, the
exporters + CLI — and the headline invariant: the INSTRUMENTED
steady-state service tick (spans + on-device metrics + SLO recording
all enabled) still performs zero host transfers under
``jax.transfer_guard("disallow")``.
"""
import json
import warnings

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import WORK_SPEC, HistogramSpec, Metrics
from repro.obs.slo import DEFAULT_LATENCY_SPEC, SLORecorder
from repro.obs.trace import EventLog


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts disabled with an empty tracer and leaves the
    process-wide state the way it found it (disabled is the default)."""
    obs.disable()
    obs.tracer().reset()
    yield
    obs.disable()
    obs.tracer().reset()


# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------

def test_histogram_quantile_matches_np_percentile_oracle():
    """Fixed-bucket quantiles vs the exact oracle over random latency
    samples: within one log-bucket (``spec.resolution()``) at p50, p90,
    and p99 — the documented error bound of the SLO layer."""
    spec = DEFAULT_LATENCY_SPEC
    rng = np.random.default_rng(7)
    for trial in range(3):
        # log-uniform latencies spanning 10µs .. 1s
        samples = 10.0 ** rng.uniform(-5, 0, size=4000)
        counts = np.zeros(spec.num_bins, np.int64)
        for s in samples:
            spec.observe(counts, s)
        for q in (0.50, 0.90, 0.99):
            est = spec.quantile(counts, q)
            true = float(np.percentile(samples, q * 100))
            ratio = est / true
            bound = spec.resolution() * 1.05
            assert 1 / bound <= ratio <= bound, (trial, q, est, true)


def test_histogram_quantile_edge_cases():
    spec = HistogramSpec(lo=1.0, hi=1000.0, num_bins=16)
    counts = np.zeros(16, np.int64)
    assert np.isnan(spec.quantile(counts, 0.5))
    spec.observe(counts, 1e-9)           # underflow bucket
    assert spec.quantile(counts, 0.5) == spec.lo
    counts[:] = 0
    spec.observe(counts, 1e9)            # overflow bucket
    assert spec.quantile(counts, 0.5) == spec.hi


def test_device_bucketing_matches_host_bucketing():
    """``bucket_device`` (the jitted scatter index) and the host
    ``bucket`` agree on every bucket boundary neighborhood."""
    import jax.numpy as jnp
    vals = np.concatenate([[0.0, 0.5, 1.0, 1.5],
                           WORK_SPEC.edges[:5] * 0.999,
                           WORK_SPEC.edges[:5] * 1.001,
                           [2.0**29, 2.0**31]]).astype(np.float32)
    host = WORK_SPEC.bucket(vals)
    dev = np.array([int(WORK_SPEC.bucket_device(jnp.asarray(v)))
                    for v in vals])
    np.testing.assert_array_equal(dev, host)


# ---------------------------------------------------------------------------
# Metrics pytree
# ---------------------------------------------------------------------------

def _mutated_metrics(seed: int) -> Metrics:
    """A Metrics accumulator after a few recorded batches (device)."""
    import jax.numpy as jnp

    from repro.core.rounds import WorkCounters
    from repro.obs.metrics import record_mutation
    rng = np.random.default_rng(seed)
    m = Metrics.zeros()
    for k in range(3):
        work = WorkCounters.zeros().add(
            hook_ops=int(rng.integers(1, 1000)),
            jump_sweeps=int(rng.integers(1, 20)))
        m = record_mutation(
            m, work, jnp.int32(int(rng.integers(1, 500))),
            jnp.int32(k), jnp.int32(k + int(rng.integers(0, 2))),
            kind="insert" if k % 2 == 0 else "delete")
    return m


def test_metrics_merge_is_associative_and_commutative():
    """Per-tenant accumulators must fold in any order: (a+b)+c ==
    a+(b+c) and a+b == b+a, leaf-exact."""
    a, b, c = (_mutated_metrics(s) for s in (1, 2, 3))
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    for l_leaf, r_leaf in zip(left, right):
        np.testing.assert_array_equal(np.asarray(l_leaf),
                                      np.asarray(r_leaf))
    ab, ba = a.merge(b), b.merge(a)
    for l_leaf, r_leaf in zip(ab, ba):
        np.testing.assert_array_equal(np.asarray(l_leaf),
                                      np.asarray(r_leaf))


def test_metrics_flush_reports_named_counters():
    from repro.obs.metrics import flush
    out = flush(_mutated_metrics(4))
    assert out["counters"]["absorbs"] == 2
    assert out["counters"]["deletes"] == 1
    assert out["counters"]["edges_absorbed"] > 0
    assert out["histograms"]["absorb_edges"]["count"] == 2
    assert "p50" in out["histograms"]["absorb_edges"]
    json.dumps(out)                      # plain-JSON by construction


# ---------------------------------------------------------------------------
# ring buffer + spans
# ---------------------------------------------------------------------------

def test_event_log_wraparound():
    log = EventLog(capacity=8)
    for i in range(20):
        log.append({"i": i})
    assert len(log) == 8
    assert log.total == 20
    assert log.dropped == 12
    assert [e["i"] for e in log.events()] == list(range(12, 20))
    log.clear()
    assert len(log) == 0 and log.dropped == 0 and log.events() == []


def test_event_log_before_wrap_keeps_everything():
    log = EventLog(capacity=8)
    for i in range(5):
        log.append({"i": i})
    assert [e["i"] for e in log.events()] == [0, 1, 2, 3, 4]
    assert log.dropped == 0


def test_disabled_span_is_shared_noop():
    """Disabled mode returns ONE shared stateless object — the <=5%
    overhead gate's mechanism (flag check, not try/except)."""
    assert not obs.enabled()
    s1, s2 = obs.span("a"), obs.span("b", tenant="t", big=1)
    assert s1 is s2
    assert s1.enabled is False
    with s1 as inner:
        inner.tag(anything=1)
    assert len(obs.tracer().log) == 0    # nothing recorded


def test_span_nesting_depth_tags_and_order():
    obs.enable()
    with obs.span("outer", tenant="t0", a=1):
        with obs.span("inner") as sp:
            sp.tag(b=2)
    evs = obs.tracer().log.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert inner["tags"] == {"b": 2}
    assert outer["tenant"] == "t0" and outer["tags"] == {"a": 1}
    assert outer["dur_us"] >= inner["dur_us"]


def test_span_records_error_and_unwinds_stack():
    obs.enable()
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (ev,) = obs.tracer().log.events()
    assert ev["error"] == "RuntimeError"
    assert obs.tracer()._stack == []


def test_jax_profiler_annotation_bridge_smoke():
    """Opt-in bridge constructs real jax.profiler annotations (no
    profiler session active — they must be harmless no-ops)."""
    obs.enable(jax_annotations=True)
    with obs.span("annotated"):
        pass
    with obs.span("stepped", step=3):    # StepTraceAnnotation path
        pass
    assert [e["name"] for e in obs.tracer().log.events()] == \
        ["annotated", "stepped"]


# ---------------------------------------------------------------------------
# exporters + CLI
# ---------------------------------------------------------------------------

def _make_trace():
    tracer = obs.enable(capacity=64)
    with obs.span("tick", tenant="a", step=1):
        with obs.span("absorb", tenant="a", edges=10):
            pass
    obs.count("autotune.miss")
    return tracer


def test_export_jsonl_roundtrip(tmp_path):
    tracer = _make_trace()
    path = tmp_path / "trace.jsonl"
    tracer.export_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    spans = [ln for ln in lines if ln["type"] == "span"]
    (tail,) = [ln for ln in lines if ln["type"] == "counters"]
    assert [s["name"] for s in spans] == ["absorb", "tick"]
    assert spans[1]["step"] == 1
    assert tail["counters"]["autotune.miss"] == 1
    assert tail["dropped"] == 0


def test_export_chrome_trace_is_perfetto_shaped(tmp_path):
    tracer = _make_trace()
    path = tmp_path / "trace.json"
    tracer.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert {"name", "ts", "dur", "pid", "tid", "args"} <= set(ev)
    args = {e["name"]: e["args"] for e in doc["traceEvents"]}
    assert args["absorb"]["edges"] == 10
    assert args["tick"]["tenant"] == "a"


def test_cli_summary_and_perfetto(tmp_path, capsys):
    from repro.obs.__main__ import main
    tracer = _make_trace()
    trace = tmp_path / "t.jsonl"
    tracer.export_jsonl(str(trace))
    assert main(["summary", str(trace)]) == 0
    out = capsys.readouterr().out
    assert "absorb" in out and "autotune.miss = 1" in out
    out_json = tmp_path / "t.json"
    assert main(["perfetto", str(trace), str(out_json)]) == 0
    assert len(json.loads(out_json.read_text())["traceEvents"]) == 2


# ---------------------------------------------------------------------------
# plan schema + facade spans
# ---------------------------------------------------------------------------

# THE as_dict schema snapshot: tracer tags and explain() both render
# from this dict — a key change here is a trace-format change and must
# be deliberate.
EXPECTED_PLAN_KEYS = [
    "backend", "batch_size", "bucket", "bucket_key", "density",
    "lift_steps", "num_edges", "num_nodes", "num_segments",
    "predicted", "reason", "segmentation",
]
EXPECTED_SEGMENTATION_KEYS = [
    "num_segments", "padded_edges", "segment_size", "source",
]


def test_plan_as_dict_schema_snapshot():
    from repro.api import Solver
    plan = Solver.open([[0, 1], [1, 2], [2, 3]], num_nodes=8).plan()
    d = plan.as_dict()
    assert sorted(d) == EXPECTED_PLAN_KEYS
    assert sorted(d["segmentation"]) == EXPECTED_SEGMENTATION_KEYS
    json.dumps(d)                        # JSON-clean by contract
    # the renderer consumes the same dict: every scalar fact in the
    # dict appears verbatim in the rendered explain()
    text = plan.explain()
    assert f"backend={d['backend']} ({d['reason']})" in text
    assert f"bucket={d['bucket_key']}" in text
    assert f"|E|={d['num_edges']}" in text
    assert d["segmentation"]["source"] in text


def test_solver_solve_span_tags_carry_plan_provenance():
    from repro.api import Solver
    obs.enable()
    s = Solver.open([[0, 1], [1, 2]], num_nodes=8, name="tenant-x")
    s.solve()
    d = s.last_plan.as_dict()
    (ev,) = [e for e in obs.tracer().log.events()
             if e["name"] == "solver.solve"]
    assert ev["tenant"] == "tenant-x"
    assert ev["tags"]["backend"] == d["backend"]
    assert ev["tags"]["reason"] in ("autotune", "heuristic")
    assert ev["tags"]["bucket"] == d["bucket_key"]
    # policy + plan.run spans nested under the facade call
    names = {e["name"] for e in obs.tracer().log.events()}
    assert {"policy.select", "plan.run"} <= names


def test_solver_mutation_spans_and_device_metrics():
    from repro.api import Solver
    obs.enable()
    s = Solver.open(num_nodes=16, name="m")
    s.insert([[0, 1], [1, 2]])
    s.insert([[2, 3]])
    s.delete([[1, 2]])
    evs = obs.tracer().log.events()
    ins = [e for e in evs if e["name"] == "solver.insert"]
    dels = [e for e in evs if e["name"] == "solver.delete"]
    assert len(ins) == 2 and len(dels) == 1
    assert all(e["tenant"] == "m" for e in ins + dels)
    assert all("route" in e["tags"] for e in ins + dels)
    # metrics attached automatically (tracing was on) and flushed
    # through the audited sink
    out = s.metrics_summary()
    counters = out["counters"]
    assert counters["absorbs"] + counters["rebuilds"] == 2
    assert counters["deletes"] + counters["rebuilds"] >= 1
    # merge across sessions == counter-wise sum
    s2 = Solver.open(num_nodes=16, name="m2")
    s2.insert([[4, 5]])
    merged = s.metrics.merge(s2.metrics)
    np.testing.assert_array_equal(
        np.asarray(merged.counts),
        np.asarray(s.metrics.counts) + np.asarray(s2.metrics.counts))


def test_query_spans_cover_all_kinds():
    from repro.api import Solver
    obs.enable()
    s = Solver.open([[0, 1], [2, 3]], num_nodes=8, name="q")
    s.same_component([[0, 1]])
    s.component_size([0, 2])
    s.num_components()
    s.component_histogram()
    names = [e["name"] for e in obs.tracer().log.events()]
    for kind in ("same_component", "component_size", "num_components",
                 "component_histogram"):
        assert f"solver.query.{kind}" in names


# ---------------------------------------------------------------------------
# always-on counters
# ---------------------------------------------------------------------------

def test_autotune_hit_miss_counters_always_on():
    from repro.connectivity import policy
    assert not obs.enabled()             # counters must not need enable()
    cache = policy.AutotuneCache()
    cache.lookup(1000, 4000)
    cache.record(1000, 4000, "adaptive", 1.0)
    cache.lookup(1000, 4000)
    cache.lookup(1000, 4000)
    c = obs.tracer().counters
    assert c["autotune.miss"] == 1
    assert c["autotune.hit"] == 2


def test_deprecation_shim_hits_counted_every_call():
    from repro import _deprecation
    _deprecation.reset()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _deprecation.warn_once("obs_test_shim", "repro.api.Solver")
        _deprecation.warn_once("obs_test_shim", "repro.api.Solver")
        _deprecation.warn_once("obs_test_shim", "repro.api.Solver")
    assert len(caught) == 1              # warn-once contract unchanged
    assert obs.tracer().counters["deprecated.obs_test_shim"] == 3


# ---------------------------------------------------------------------------
# SLO recorder
# ---------------------------------------------------------------------------

def test_slo_recorder_per_tenant_and_exact_global_merge():
    rec = SLORecorder()
    rng = np.random.default_rng(0)
    lat_a = 10.0 ** rng.uniform(-4, -2, 500)     # 100µs..10ms
    lat_b = 10.0 ** rng.uniform(-3, -1, 500)     # 1ms..100ms
    for v in lat_a:
        rec.record("a", "same_component", float(v))
    for v in lat_b:
        rec.record("b", "same_component", float(v))
    summ = rec.summary()
    assert set(summ["tenants"]) == {"a", "b"}
    row_a = summ["tenants"]["a"]["same_component"]
    assert row_a["count"] == 500
    assert row_a["p50_ms"] <= row_a["p90_ms"] <= row_a["p99_ms"]
    # global = exact bucket merge, not an average of percentiles
    g = summ["global"]["same_component"]
    assert g["count"] == 1000
    merged = rec.merged(kinds=("same_component",))
    assert g["p99_ms"] == round(merged.quantile(0.99) * 1e3, 4)
    bound = rec.spec.resolution() * 1.05
    both = np.concatenate([lat_a, lat_b])
    true_p50 = float(np.percentile(both, 50))
    est_p50 = merged.quantile(0.50)
    assert 1 / bound <= est_p50 / true_p50 <= bound


def test_slo_merge_rejects_mismatched_bucket_specs():
    """Bucket counts only add exactly when every stream shares one
    edge layout. A recorder whose ``_hists`` were populated externally
    (the fleet's per-device merge path) with a different spec must
    raise, not silently read percentiles off the wrong edges — and the
    same check guards ``merge_recorders`` at both the recorder and the
    per-stream level."""
    from repro.obs.metrics import HistogramSpec
    from repro.obs.slo import LatencyHistogram, merge_recorders

    other_spec = HistogramSpec(lo=1e-3, hi=1.0, num_bins=8)
    rec = SLORecorder()
    rec.record("a", "insert", 0.01)
    # smuggle a foreign-layout stream in, the way an external populator
    # (bad merge code) would
    rec._hists[("b", "insert")] = LatencyHistogram(other_spec)
    rec._hists[("b", "insert")].record(0.01)
    with pytest.raises(ValueError, match="not mergeable"):
        rec.merged()
    # per-tenant read that avoids the bad stream still works
    assert rec.merged(tenant="a").count == 1

    # recorder-level mismatch
    r1, r2 = SLORecorder(), SLORecorder(other_spec)
    r1.record("a", "insert", 0.01)
    r2.record("a", "insert", 0.01)
    with pytest.raises(ValueError, match="not mergeable"):
        merge_recorders([r1, r2])
    # stream-level mismatch behind a matching recorder spec
    r3 = SLORecorder()
    r3._hists[("c", "insert")] = LatencyHistogram(other_spec)
    with pytest.raises(ValueError, match="spec"):
        merge_recorders([r1, r3])
    # clean merge is exact: counts sum per (tenant, kind)
    r4 = SLORecorder()
    r4.record("a", "insert", 0.02)
    r4.record("d", "query", 0.001)
    out = merge_recorders([r1, r4])
    assert out.merged(tenant="a").count == 2
    assert out.merged(tenant="d").count == 1


# ---------------------------------------------------------------------------
# the headline contract: instrumented tick stays transfer-free
# ---------------------------------------------------------------------------

def test_instrumented_service_tick_stays_transfer_free():
    """Spans + on-device Metrics + SLO recording all ENABLED: the
    steady-state coalesced insert AND delete ticks still perform zero
    host transfers (``jax.transfer_guard("disallow")``); telemetry
    materializes only at the explicit ``obs_summary()`` flush."""
    import jax

    import repro.graphs.generators as G
    from repro.connectivity.registry import GraphRegistry
    from repro.connectivity.service import ConnectivityService
    from repro.graphs.device import DeviceGraph

    obs.enable(capacity=4096)
    g = G.grid_road(8, extra_prob=0.0, seed=0)
    n, edges = g.num_nodes, np.asarray(g.edges, np.int32)
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=16)
    reg.create("t", n)                   # metrics attach (tracing on)
    # warm every jit entry the steady state will hit — including the
    # record_mutation fold (its first call compiles + transfers consts)
    svc.submit_insert("t", edges[:-40])
    svc.run()
    svc.submit_insert("t", edges[-40:-30])
    svc.submit_insert("t", edges[-30:-20])
    svc.run()
    svc.submit_delete("t", edges[:5])
    svc.submit_delete("t", edges[5:10])
    svc.run()

    # steady state, same shapes, instrumentation live
    svc.submit_insert("t", DeviceGraph.from_edges(edges[-20:-10], n))
    svc.submit_insert("t", DeviceGraph.from_edges(edges[-10:], n))
    svc.submit_delete("t", DeviceGraph.from_edges(edges[10:15], n))
    svc.submit_delete("t", DeviceGraph.from_edges(edges[15:20], n))
    with jax.transfer_guard("disallow"):
        finished = svc.run()
    assert [r.error for r in finished] == [None] * 4

    # the guarded ticks actually recorded telemetry
    names = [e["name"] for e in obs.tracer().log.events()]
    assert "service.tick" in names
    assert "service.insert" in names and "service.delete" in names
    assert svc.slo.hist("t", "insert") is not None
    summary = svc.obs_summary()          # the one explicit sync
    dm = summary["device_metrics"]
    assert dm is not None
    assert dm["counters"]["absorbs"] >= 2
    assert dm["counters"]["deletes"] >= 2
    assert summary["latency"]["tenants"]["t"]["insert"]["count"] >= 2


def test_service_query_latency_lands_in_slo():
    from repro.connectivity.registry import GraphRegistry
    from repro.connectivity.service import ConnectivityService

    obs.enable()
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=8)
    reg.create("t", 16)
    svc.submit_insert("t", [[0, 1], [1, 2]])
    svc.submit_query("t", "same_component", [[0, 2], [0, 3]])
    svc.submit_query("t", "count_components")
    svc.run()
    summ = svc.slo.summary()
    t_rows = summ["tenants"]["t"]
    assert t_rows["same_component"]["count"] == 1
    assert t_rows["count_components"]["count"] == 1
    assert t_rows["same_component"]["p50_ms"] > 0


def test_service_slo_not_recorded_when_disabled():
    from repro.connectivity.registry import GraphRegistry
    from repro.connectivity.service import ConnectivityService

    assert not obs.enabled()
    reg = GraphRegistry()
    svc = ConnectivityService(reg, slots=8)
    reg.create("t", 16)
    svc.submit_insert("t", [[0, 1]])
    svc.submit_query("t", "count_components")
    svc.run()
    assert svc.slo.summary()["tenants"] == {}
    assert len(obs.tracer().log) == 0
