"""Shared property-based graph strategies + the deterministic named
corpus, for every test module in the suite.

Before this module, ``test_cc``, ``test_batch_incremental``, and
``test_connectivity`` each rolled their own inline ``st.integers(...)
.flatmap(...)`` edge-list generators — three slightly different
distributions, none covering the named degenerate families. Everything
here is built ONLY on the strategy surface ``tests/_propcheck.py``
guarantees (``integers / lists / tuples / just`` + ``map`` /
``flatmap``), so one definition works under real hypothesis and under
the deterministic fallback alike.

Two layers:

* **``corpus()``** — deterministic named cases (ER, star, chain,
  forest, two-cliques-one-bridge, empty, self-loop, duplicate-edge,
  power-of-two padding boundaries). The conformance matrix iterates
  this exhaustively; property tests fuzz AROUND it.
* **strategies** — ``edge_lists`` (the shared (n, edges) case),
  ``edge_list_batches`` (batched engines), ``graph_with_query_pairs``
  (query kernels), ``insert_batch_cases`` (registry streams), and
  ``dynamic_scripts`` (interleaved insert/delete scripts for the
  fully-dynamic engine — vertex ranges are kept small so drawn deletes
  actually hit live edges).
"""
from __future__ import annotations

import numpy as np

from _propcheck import st


def edges_array(edges) -> np.ndarray:
    """Canonical int32 [E, 2] spelling of a drawn edge list."""
    return np.asarray(edges, np.int32).reshape(-1, 2)


# ---------------------------------------------------------------------------
# Deterministic named corpus
# ---------------------------------------------------------------------------

def _chain(n):
    return [[i, i + 1] for i in range(n - 1)]


def _star(n):
    return [[0, i] for i in range(1, n)]


def _forest(n, arity, seed):
    """Random forest: every vertex > 0 either roots a new tree or hangs
    off an earlier vertex — no cycles, so EVERY edge is a bridge (the
    deletion worst case)."""
    rng = np.random.default_rng(seed)
    edges = []
    for v in range(1, n):
        if rng.random() < 1.0 / arity:
            continue                    # v roots its own tree
        edges.append([int(rng.integers(0, v)), v])
    return edges


def _clique(vertices):
    return [[u, v] for i, u in enumerate(vertices)
            for v in vertices[i + 1:]]


def two_cliques_one_bridge(k1: int, k2: int):
    """Two cliques joined by a single bridge — the canonical split
    scenario: deleting any clique edge keeps the partition, deleting
    the bridge splits it. Returns (num_nodes, edges, bridge)."""
    a = list(range(k1))
    b = list(range(k1, k1 + k2))
    bridge = [a[-1], b[0]]
    return k1 + k2, _clique(a) + [bridge] + _clique(b), bridge


def _er(n, e, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, (e, 2)).tolist()


def power_law(n, e, seed, alpha: float = 1.0):
    """Skewed-degree (RMAT/power-law-style) graph: endpoints drawn with
    probability proportional to 1/(rank+1)^alpha, so low-id vertices
    become hubs (max_degree >> mean_degree — the regime where the
    sampling phase collapses the giant component; road/ER graphs sit
    near skew 1). Self loops and duplicates occur by construction,
    like ``_er``."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), alpha)
    w /= w.sum()
    return rng.choice(n, size=(e, 2), p=w).tolist()


def corpus():
    """The deterministic named cases: ``(name, num_nodes, edges)`` with
    ``edges`` an int32 [E, 2] array. Covers every generator family the
    ISSUE names plus the power-of-two padding boundaries (|E| exactly
    at / one off a bucket edge, where prefix-padding bugs live)."""
    n2, e2, _ = two_cliques_one_bridge(5, 4)
    cases = [
        ("empty-0v", 0, []),
        ("empty-6v", 6, []),
        ("single-vertex", 1, []),
        ("self-loop", 4, [[1, 1], [3, 3], [0, 2]]),
        ("duplicate-edge", 5, [[0, 1], [0, 1], [1, 0], [2, 3], [2, 3]]),
        ("chain-17", 17, _chain(17)),
        ("star-13", 13, _star(13)),
        ("forest-19", 19, _forest(19, 3, seed=7)),
        ("two-cliques-bridge", n2, e2),
        ("er-sparse", 30, _er(30, 18, seed=11)),
        ("er-mid", 24, _er(24, 60, seed=12)),
        ("er-dense", 10, _er(10, 70, seed=13)),
        # skewed-degree (power-law) — the sampled backends' home turf;
        # sized under the policy's SAMPLED_MIN_EDGES floor so "auto"
        # corpus routing stays on the exact engines
        ("powerlaw-64", 64, power_law(64, 256, seed=31)),
        ("powerlaw-256", 256, power_law(256, 1024, seed=32)),
        # pow2 padding boundaries: E at a bucket edge and one past it,
        # V exactly at / one past a pow2 (bucket height boundaries)
        ("pow2-E8", 12, _er(12, 8, seed=21)),
        ("pow2-E9", 12, _er(12, 9, seed=22)),
        ("pow2-E16", 16, _er(16, 16, seed=23)),
        ("pow2-E17", 16, _er(16, 17, seed=24)),
        ("pow2-V8", 8, _er(8, 12, seed=25)),
        ("pow2-V9", 9, _er(9, 12, seed=26)),
    ]
    return [(name, n, edges_array(e)) for name, n, e in cases]


# ---------------------------------------------------------------------------
# Strategies (fallback-compatible surface only)
# ---------------------------------------------------------------------------

def _edge(n):
    return st.tuples(st.integers(0, n - 1), st.integers(0, n - 1))


def edge_cases(min_n: int = 2, max_n: int = 40, max_edges: int = 120,
               min_edges: int = 0):
    """The suite's shared random-graph case: draws ``(n, edges)`` with
    uniform (ER-style) endpoints — self loops and duplicates included
    by construction."""
    return st.integers(min_n, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(_edge(n), min_size=min_edges, max_size=max_edges)))


# the exact shape test_cc historically used, now shared
edge_lists = edge_cases(2, 40, 120)

# batched engines: several (n, edges) cases per draw
edge_list_batches = st.lists(edge_cases(2, 24, 40), min_size=1,
                             max_size=6)


def graph_with_query_pairs(max_n: int = 30, max_edges: int = 50,
                           max_pairs: int = 20):
    """(n, edges, query_pairs) for the query-kernel properties."""
    return st.integers(1, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(_edge(n), min_size=0, max_size=max_edges),
            st.lists(_edge(n), min_size=1, max_size=max_pairs)))


def insert_batch_cases(min_n: int = 8, max_n: int = 28,
                       max_batch: int = 12, max_batches: int = 6):
    """(n, [batch, ...]) insert streams for the registry properties."""
    return st.integers(min_n, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(st.lists(_edge(n), min_size=0, max_size=max_batch),
                     min_size=1, max_size=max_batches)))


def dynamic_scripts(max_n: int = 12, max_ops: int = 8,
                    max_batch: int = 8):
    """Interleaved insert/delete scripts for the fully-dynamic engine:
    ``(n, [(op, edges), ...])`` with ``op`` 0 = insert, 1 = delete.
    The vertex range is deliberately small so drawn deletes collide
    with live edges often (bridges, duplicate retirement, and absent
    no-ops all get exercised); both-endpoint draws also produce
    self-loop deletes."""
    return st.integers(3, max_n).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(
                st.tuples(st.integers(0, 1),
                          st.lists(_edge(n), min_size=0,
                                   max_size=max_batch)),
                min_size=1, max_size=max_ops)))
