"""Batched + incremental CC engines on the shared adaptive core:
bit-identity with the single-graph path, oracle agreement under
streaming insertions, and true-edge work billing."""
import numpy as np
import pytest

from _graphgen import dynamic_scripts, edge_list_batches, edges_array
from _propcheck import given, settings, st
from repro.core import rounds
from repro.core.batch import (bucket_shape, bucketize,
                              connected_components_batched)
from repro.core.cc import (connected_components,
                           connected_components_hostloop, num_components)
from repro.core.incremental import DynamicCC, IncrementalCC
from repro.core.segmentation import plan_segmentation
from repro.core.unionfind import (DynamicConnectivityOracle,
                                  connected_components_oracle)
from repro.graphs import generators as G


def mixed_graphs():
    return [
        G.chain(17),
        G.star(9),
        G.disjoint_cliques(4, 5),
        G.grid_road(8, seed=1),
        G.rmat(6, 4, seed=3),
        G.chain(2),
        # zero-edge graph: 5 isolated vertices
        G.Graph(edges=np.zeros((0, 2), np.int64), num_nodes=5),
    ]


# --------------------------------------------------------------------------
# Batched engine
# --------------------------------------------------------------------------

def test_batched_bit_identical_to_per_graph():
    graphs = mixed_graphs()
    batched = connected_components_batched(graphs)
    assert len(batched) == len(graphs)
    for g, res in zip(graphs, batched):
        single = connected_components(g.edges, g.num_nodes)
        want = connected_components_oracle(g.edges, g.num_nodes)
        np.testing.assert_array_equal(np.asarray(res.labels), want,
                                      err_msg=g.name)
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      np.asarray(single.labels),
                                      err_msg=g.name)
        assert res.labels.shape == (g.num_nodes,)


def test_batched_accepts_edge_tuples():
    pairs = [(np.array([[0, 1], [1, 2]]), 4),
             (np.array([[0, 3]]), 5)]
    out = connected_components_batched(pairs)
    np.testing.assert_array_equal(np.asarray(out[0].labels), [0, 0, 0, 3])
    np.testing.assert_array_equal(np.asarray(out[1].labels),
                                  [0, 1, 2, 0, 4])


def test_bucketize_groups_by_padded_shape():
    graphs = [(np.zeros((3, 2)), 7), (np.zeros((4, 2)), 8),
              (np.zeros((100, 2)), 7)]
    batches = bucketize(graphs)
    shapes = {(b.num_nodes, b.edges.shape[1]) for b in batches}
    assert (8, 8) in shapes          # the two small graphs share a bucket
    assert (8, 128) in shapes
    sizes = sorted(b.edges.shape[0] for b in batches)
    assert sizes == [1, 2]
    assert bucket_shape(7, 3) == (8, 8)
    assert bucket_shape(9, 129) == (16, 256)


def test_batched_work_bills_true_edges_only():
    """hook_ops must be a multiple of E_true * (1 + lift_steps) even
    though the bucket pads the edge list (padding is free)."""
    g = G.chain(17)           # 16 edges -> padded to 32 in its bucket
    res = connected_components_batched([g], lift_steps=2)[0]
    bill = g.num_edges * 3
    assert int(res.work.hook_ops) % bill == 0
    assert int(res.work.hook_ops) >= bill
    # jump_ops bill the true |V| per sweep, not the padded bucket height
    assert int(res.work.jump_ops) == \
        g.num_nodes * int(res.work.jump_sweeps)


@settings(max_examples=8, deadline=None)
@given(edge_list_batches)
def test_batched_matches_oracle_property(cases):
    pairs = [(edges_array(e), n) for n, e in cases]
    out = connected_components_batched(pairs)
    for (edges, n), res in zip(pairs, out):
        want = connected_components_oracle(edges, n)
        np.testing.assert_array_equal(np.asarray(res.labels), want)


# --------------------------------------------------------------------------
# Incremental engine
# --------------------------------------------------------------------------

def test_incremental_matches_oracle_over_batches():
    n = 60
    rng = np.random.default_rng(7)
    inc = IncrementalCC(n)
    accumulated = np.zeros((0, 2), np.int32)
    for size in (5, 1, 17, 0, 9, 30):
        batch = rng.integers(0, n, (size, 2)).astype(np.int32)
        inc.insert(batch)
        accumulated = np.concatenate([accumulated, batch], axis=0)
        want = connected_components_oracle(accumulated, n)
        np.testing.assert_array_equal(np.asarray(inc.labels), want)
    assert inc.num_edges_inserted == accumulated.shape[0]
    assert inc.num_components() == num_components(want)


def test_incremental_noop_batch_costs_zero_hook_rounds():
    inc = IncrementalCC(10)
    inc.insert([[0, 1], [1, 2], [3, 4]])
    before = dict(inc.work)
    inc.insert([[0, 2], [2, 1], [4, 3]])   # all already connected
    assert inc.work["hook_rounds"] == before["hook_rounds"]
    assert inc.work["hook_ops"] == before["hook_ops"]
    np.testing.assert_array_equal(
        np.asarray(inc.labels),
        connected_components_oracle(
            np.array([[0, 1], [1, 2], [3, 4]]), 10))


def test_incremental_rejects_out_of_range():
    inc = IncrementalCC(4)
    with pytest.raises(ValueError):
        inc.insert([[0, 4]])
    with pytest.raises(ValueError):
        inc.insert([[-1, 2]])
    with pytest.raises(ValueError):
        inc.connected(0, 4)            # JAX would clamp, not error
    with pytest.raises(ValueError):
        inc.connected(-1, 0)


def test_incremental_work_cheaper_than_recompute():
    """The incremental absorb hooks only the new edges; a from-scratch
    adaptive run re-hooks the full accumulated edge list every batch."""
    n = 256
    rng = np.random.default_rng(3)
    batches = [rng.integers(0, n, (32, 2)).astype(np.int32)
               for _ in range(8)]
    inc = IncrementalCC(n)
    full_hook_ops = 0
    acc = np.zeros((0, 2), np.int32)
    for b in batches:
        inc.insert(b)
        acc = np.concatenate([acc, b], axis=0)
        full = connected_components(acc, n, method="adaptive")
        full_hook_ops += int(full.work.hook_ops)
    assert inc.work["hook_ops"] < full_hook_ops


def test_incremental_empty_graph():
    inc = IncrementalCC(0)
    inc.insert(np.zeros((0, 2), np.int32))
    assert inc.labels.shape == (0,)


# --------------------------------------------------------------------------
# Fully-dynamic engine (DESIGN.md §9): deletions
# --------------------------------------------------------------------------

def run_script(dyn: DynamicCC, script, n: int,
               check_every_step: bool = True):
    """Drive a dynamic engine and the host oracle through one
    interleaved insert/delete script, asserting label agreement."""
    oracle = DynamicConnectivityOracle(n)
    for op, batch in script:
        edges = edges_array(batch)
        if op == 0:
            dyn.insert(edges)
            oracle.insert(edges)
        else:
            dyn.delete(edges)
            oracle.delete(edges)
        if check_every_step:
            np.testing.assert_array_equal(np.asarray(dyn.labels),
                                          oracle.labels(),
                                          err_msg=str(script))
    return oracle


@settings(max_examples=10, deadline=None)
@given(dynamic_scripts())
def test_dynamic_matches_oracle_over_scripts(case):
    """Acceptance: after EVERY step of any interleaved insert/delete
    script, DynamicCC's labels equal a from-scratch union-find (and
    scipy) recompute over the surviving edge multiset."""
    n, script = case
    run_script(DynamicCC(n), script, n)


def test_dynamic_bridge_delete_splits_nonbridge_does_not():
    """The split detector: deleting a cycle edge keeps the partition
    (version unchanged, zero stale risk), deleting the bridge splits
    it (version ticks)."""
    from _graphgen import two_cliques_one_bridge
    n, edges, bridge = two_cliques_one_bridge(5, 4)
    dyn = DynamicCC(n)
    dyn.insert(edges)
    v0 = dyn.version
    dyn.delete([edges[0]])              # clique-internal: not a bridge
    assert dyn.version == v0
    assert dyn.num_components() == 1
    dyn.delete([bridge])                # the bridge: an actual split
    assert dyn.version == v0 + 1
    assert dyn.num_components() == 2
    assert not dyn.connected(0, n - 1)
    np.testing.assert_array_equal(
        np.asarray(dyn.labels),
        connected_components_oracle(
            edges_array([e for e in edges
                         if e not in (edges[0], bridge)]), n))


def test_dynamic_absent_delete_is_free_and_silent():
    """Deleting absent edges (or double-deleting) retires nothing:
    zero hook rounds, zero sweeps, no version tick."""
    dyn = DynamicCC(10)
    dyn.insert([[0, 1], [1, 2], [3, 4]])
    dyn.delete([[3, 4]])
    v0, before = dyn.version, dict(dyn.work)
    dyn.delete([[5, 6], [3, 4], [7, 7]])     # absent + double + loop
    after = dyn.work
    assert dyn.version == v0
    assert after["hook_rounds"] == before["hook_rounds"]
    assert after["jump_sweeps"] == before["jump_sweeps"]
    assert after["hook_ops"] == before["hook_ops"]
    assert dyn.num_edges_deleted == 1


def test_dynamic_delete_retires_every_copy_orientation_blind():
    dyn = DynamicCC(6)
    dyn.insert([[0, 1], [1, 0], [0, 1], [2, 3]])
    dyn.delete([[1, 0]])                # kills all three copies
    assert dyn.num_edges_deleted == 3
    assert dyn.num_edges_alive == 1
    assert not dyn.connected(0, 1)


def test_dynamic_scoped_recompute_cheaper_than_full():
    """The paper's currency: a bridge deletion inside ONE of many
    components re-hooks only that component's survivors — hook_ops
    must undercut a from-scratch recompute of the whole graph."""
    g = G.disjoint_cliques(6, 8, seed=0)      # 6 components, 28 edges each
    edges = np.asarray(g.edges, np.int32)
    dyn = DynamicCC(g.num_nodes)
    dyn.insert(edges)
    base = dyn.work["hook_ops"]
    dyn.delete([edges[0]])                    # one clique-internal edge
    scoped_ops = dyn.work["hook_ops"] - base
    oracle = DynamicConnectivityOracle(g.num_nodes)
    oracle.insert(edges)
    oracle.delete([edges[0]])
    full = connected_components(edges_array(oracle.alive()),
                                g.num_nodes, method="adaptive")
    np.testing.assert_array_equal(np.asarray(dyn.labels), oracle.labels())
    assert 0 < scoped_ops < int(full.work.hook_ops), \
        (scoped_ops, int(full.work.hook_ops))


def test_dynamic_forest_compaction_remaps_parent_eidx():
    """ISSUE 9 satellite: ``DynamicCC.compact()`` packs the tombstone
    log AND remaps the maintained forest's ``parent_eidx`` through the
    compaction permutation in one step — afterwards every recorded
    pointer still names the ALIVE log row holding its parent edge, and
    the forest route keeps working over the renumbered log."""
    from repro.graphs.device import DeviceGraph

    rng = np.random.default_rng(11)
    n = 32
    edges = rng.integers(0, n, (48, 2)).astype(np.int32)
    dyn = DynamicCC(n)
    oracle = DynamicConnectivityOracle(n)
    dyn.insert(edges)
    oracle.insert(edges)
    assert dyn.forest_valid                  # inserts never stale it
    kills = edges[::3].copy()
    dyn.delete_graph_forest(DeviceGraph.from_edges(kills, n))
    oracle.delete(kills)
    np.testing.assert_array_equal(np.asarray(dyn.labels), oracle.labels())

    labels_before = np.asarray(dyn.labels).copy()
    rows_before = dyn.log.rows
    dyn.compact()
    assert dyn.log.rows < rows_before        # tombstones dropped
    assert dyn.forest_valid
    np.testing.assert_array_equal(np.asarray(dyn.labels), labels_before)
    parents = np.asarray(dyn.forest[0])
    eidx = np.asarray(dyn.forest[1])
    log_e = np.asarray(dyn.log.edges)
    log_a = np.asarray(dyn.log.alive)
    recorded = np.flatnonzero(parents[:, 0] >= 0)
    assert recorded.size > 0
    for r in recorded:
        k = int(eidx[r])
        assert 0 <= k < dyn.log.rows, (int(r), k)
        assert bool(log_a[k]), (int(r), k)
        assert (sorted(map(int, log_e[k]))
                == sorted(map(int, parents[r]))), (int(r), k)
    # the forest keeps working post-compaction: kill a live tree edge
    tree0 = [sorted(map(int, parents[recorded[0]]))]
    dyn.delete_graph_forest(DeviceGraph.from_edges(tree0, n))
    oracle.delete(tree0)
    np.testing.assert_array_equal(np.asarray(dyn.labels), oracle.labels())


def test_dynamic_plain_delete_stales_forest_lazy_rebuild():
    """A plain (non-forest) delete leaves the maintained forest stale;
    the next forest-route call lazily rebuilds it exactly once (counted
    in ``delete_route_counts()['rebuild']``) and lands on the same
    labels as the oracle."""
    from repro.graphs.device import DeviceGraph

    n = 16
    ring = [[i, (i + 1) % n] for i in range(n)]
    dyn = DynamicCC(n)
    oracle = DynamicConnectivityOracle(n)
    dyn.insert(ring)
    oracle.insert(ring)
    dyn.delete([[0, 1]])                     # plain route: forest stales
    oracle.delete([[0, 1]])
    assert not dyn.forest_valid
    dyn.delete_graph_forest(DeviceGraph.from_edges([[4, 5]], n))
    oracle.delete([[4, 5]])
    assert dyn.forest_valid and dyn.forest_rebuilds == 1
    assert dyn.delete_route_counts()["rebuild"] == 1
    np.testing.assert_array_equal(np.asarray(dyn.labels), oracle.labels())


def test_dynamic_fused_scan_bit_identical():
    """scan_method='pallas_fused' runs the scoped recompute through the
    fused kernel: labels AND work counters bit-identical to jnp."""
    rng = np.random.default_rng(5)
    n = 48
    edges = rng.integers(0, n, (70, 2))
    kills = edges[rng.integers(0, 70, 15)]
    a = DynamicCC(n)
    b = DynamicCC(n, scan_method="pallas_fused")
    for dyn in (a, b):
        dyn.insert(edges)
        dyn.delete(kills)
    np.testing.assert_array_equal(np.asarray(a.labels),
                                  np.asarray(b.labels))
    assert a.work == b.work


def test_dynamic_validation_and_degenerate():
    dyn = DynamicCC(4)
    with pytest.raises(ValueError):
        dyn.delete([[0, 4]])
    with pytest.raises(ValueError):
        dyn.delete([[-1, 0]])
    with pytest.raises(ValueError):
        DynamicCC(4, scan_method="nope")
    dyn.delete(np.zeros((0, 2), np.int32))   # empty batch: no-op
    dyn.delete([[0, 1]])                     # delete before any insert
    assert dyn.num_edges_deleted == 0
    empty = DynamicCC(0)
    empty.insert(np.zeros((0, 2)))
    empty.delete(np.zeros((0, 2)))
    assert empty.labels.shape == (0,)


# --------------------------------------------------------------------------
# Shared rounds core: billing + API contracts
# --------------------------------------------------------------------------

def test_segment_true_counts_sum_to_true_edges():
    plan = plan_segmentation(100, 30)        # pads 100 edges over s segs
    counts = np.asarray(rounds.segment_true_counts(100, plan))
    assert counts.shape == (plan.num_segments,)
    assert counts.sum() == 100
    assert counts.max() <= plan.segment_size


def test_adaptive_hook_ops_bill_true_edges():
    """Single-graph adaptive billing: with padding present, hook_ops is
    (1 + cleanup_rounds) * E_true * (1 + lift_steps) — never a function
    of the padded segment size."""
    g = G.chain(17)                          # 16 edges
    lift, segs = 2, 3                        # seg=6 -> 18 padded slots
    plan = plan_segmentation(g.num_edges, g.num_nodes, segs)
    assert plan.padded_edges > g.num_edges   # the scenario under test
    res = connected_components(g.edges, g.num_nodes, method="adaptive",
                               num_segments=segs, lift_steps=lift)
    cleanup = int(res.work.hook_rounds) - plan.num_segments
    assert cleanup >= 0
    # the old (buggy) padded billing would have charged
    # plan.segment_size per scan segment instead of the true count
    assert int(res.work.hook_ops) == \
        (1 + cleanup) * g.num_edges * (1 + lift)


def test_hostloop_unknown_method_raises():
    g = G.chain(5)
    with pytest.raises(ValueError, match="unknown method"):
        connected_components_hostloop(g.edges, g.num_nodes,
                                      method="adaptive")
