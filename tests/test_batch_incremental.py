"""Batched + incremental CC engines on the shared adaptive core:
bit-identity with the single-graph path, oracle agreement under
streaming insertions, and true-edge work billing."""
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import rounds
from repro.core.batch import (bucket_shape, bucketize,
                              connected_components_batched)
from repro.core.cc import (connected_components,
                           connected_components_hostloop, num_components)
from repro.core.incremental import IncrementalCC
from repro.core.segmentation import plan_segmentation
from repro.core.unionfind import connected_components_oracle
from repro.graphs import generators as G


def mixed_graphs():
    return [
        G.chain(17),
        G.star(9),
        G.disjoint_cliques(4, 5),
        G.grid_road(8, seed=1),
        G.rmat(6, 4, seed=3),
        G.chain(2),
        # zero-edge graph: 5 isolated vertices
        G.Graph(edges=np.zeros((0, 2), np.int64), num_nodes=5),
    ]


# --------------------------------------------------------------------------
# Batched engine
# --------------------------------------------------------------------------

def test_batched_bit_identical_to_per_graph():
    graphs = mixed_graphs()
    batched = connected_components_batched(graphs)
    assert len(batched) == len(graphs)
    for g, res in zip(graphs, batched):
        single = connected_components(g.edges, g.num_nodes)
        want = connected_components_oracle(g.edges, g.num_nodes)
        np.testing.assert_array_equal(np.asarray(res.labels), want,
                                      err_msg=g.name)
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      np.asarray(single.labels),
                                      err_msg=g.name)
        assert res.labels.shape == (g.num_nodes,)


def test_batched_accepts_edge_tuples():
    pairs = [(np.array([[0, 1], [1, 2]]), 4),
             (np.array([[0, 3]]), 5)]
    out = connected_components_batched(pairs)
    np.testing.assert_array_equal(np.asarray(out[0].labels), [0, 0, 0, 3])
    np.testing.assert_array_equal(np.asarray(out[1].labels),
                                  [0, 1, 2, 0, 4])


def test_bucketize_groups_by_padded_shape():
    graphs = [(np.zeros((3, 2)), 7), (np.zeros((4, 2)), 8),
              (np.zeros((100, 2)), 7)]
    batches = bucketize(graphs)
    shapes = {(b.num_nodes, b.edges.shape[1]) for b in batches}
    assert (8, 8) in shapes          # the two small graphs share a bucket
    assert (8, 128) in shapes
    sizes = sorted(b.edges.shape[0] for b in batches)
    assert sizes == [1, 2]
    assert bucket_shape(7, 3) == (8, 8)
    assert bucket_shape(9, 129) == (16, 256)


def test_batched_work_bills_true_edges_only():
    """hook_ops must be a multiple of E_true * (1 + lift_steps) even
    though the bucket pads the edge list (padding is free)."""
    g = G.chain(17)           # 16 edges -> padded to 32 in its bucket
    res = connected_components_batched([g], lift_steps=2)[0]
    bill = g.num_edges * 3
    assert int(res.work.hook_ops) % bill == 0
    assert int(res.work.hook_ops) >= bill
    # jump_ops bill the true |V| per sweep, not the padded bucket height
    assert int(res.work.jump_ops) == \
        g.num_nodes * int(res.work.jump_sweeps)


@settings(max_examples=8, deadline=None)
@given(st.lists(
    st.integers(2, 24).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(st.tuples(st.integers(0, n - 1),
                               st.integers(0, n - 1)),
                     min_size=0, max_size=40))),
    min_size=1, max_size=6))
def test_batched_matches_oracle_property(cases):
    pairs = [(np.asarray(e, np.int32).reshape(-1, 2), n)
             for n, e in cases]
    out = connected_components_batched(pairs)
    for (edges, n), res in zip(pairs, out):
        want = connected_components_oracle(edges, n)
        np.testing.assert_array_equal(np.asarray(res.labels), want)


# --------------------------------------------------------------------------
# Incremental engine
# --------------------------------------------------------------------------

def test_incremental_matches_oracle_over_batches():
    n = 60
    rng = np.random.default_rng(7)
    inc = IncrementalCC(n)
    accumulated = np.zeros((0, 2), np.int32)
    for size in (5, 1, 17, 0, 9, 30):
        batch = rng.integers(0, n, (size, 2)).astype(np.int32)
        inc.insert(batch)
        accumulated = np.concatenate([accumulated, batch], axis=0)
        want = connected_components_oracle(accumulated, n)
        np.testing.assert_array_equal(np.asarray(inc.labels), want)
    assert inc.num_edges_inserted == accumulated.shape[0]
    assert inc.num_components() == num_components(want)


def test_incremental_noop_batch_costs_zero_hook_rounds():
    inc = IncrementalCC(10)
    inc.insert([[0, 1], [1, 2], [3, 4]])
    before = dict(inc.work)
    inc.insert([[0, 2], [2, 1], [4, 3]])   # all already connected
    assert inc.work["hook_rounds"] == before["hook_rounds"]
    assert inc.work["hook_ops"] == before["hook_ops"]
    np.testing.assert_array_equal(
        np.asarray(inc.labels),
        connected_components_oracle(
            np.array([[0, 1], [1, 2], [3, 4]]), 10))


def test_incremental_rejects_out_of_range():
    inc = IncrementalCC(4)
    with pytest.raises(ValueError):
        inc.insert([[0, 4]])
    with pytest.raises(ValueError):
        inc.insert([[-1, 2]])
    with pytest.raises(ValueError):
        inc.connected(0, 4)            # JAX would clamp, not error
    with pytest.raises(ValueError):
        inc.connected(-1, 0)


def test_incremental_work_cheaper_than_recompute():
    """The incremental absorb hooks only the new edges; a from-scratch
    adaptive run re-hooks the full accumulated edge list every batch."""
    n = 256
    rng = np.random.default_rng(3)
    batches = [rng.integers(0, n, (32, 2)).astype(np.int32)
               for _ in range(8)]
    inc = IncrementalCC(n)
    full_hook_ops = 0
    acc = np.zeros((0, 2), np.int32)
    for b in batches:
        inc.insert(b)
        acc = np.concatenate([acc, b], axis=0)
        full = connected_components(acc, n, method="adaptive")
        full_hook_ops += int(full.work.hook_ops)
    assert inc.work["hook_ops"] < full_hook_ops


def test_incremental_empty_graph():
    inc = IncrementalCC(0)
    inc.insert(np.zeros((0, 2), np.int32))
    assert inc.labels.shape == (0,)


# --------------------------------------------------------------------------
# Shared rounds core: billing + API contracts
# --------------------------------------------------------------------------

def test_segment_true_counts_sum_to_true_edges():
    plan = plan_segmentation(100, 30)        # pads 100 edges over s segs
    counts = np.asarray(rounds.segment_true_counts(100, plan))
    assert counts.shape == (plan.num_segments,)
    assert counts.sum() == 100
    assert counts.max() <= plan.segment_size


def test_adaptive_hook_ops_bill_true_edges():
    """Single-graph adaptive billing: with padding present, hook_ops is
    (1 + cleanup_rounds) * E_true * (1 + lift_steps) — never a function
    of the padded segment size."""
    g = G.chain(17)                          # 16 edges
    lift, segs = 2, 3                        # seg=6 -> 18 padded slots
    plan = plan_segmentation(g.num_edges, g.num_nodes, segs)
    assert plan.padded_edges > g.num_edges   # the scenario under test
    res = connected_components(g.edges, g.num_nodes, method="adaptive",
                               num_segments=segs, lift_steps=lift)
    cleanup = int(res.work.hook_rounds) - plan.num_segments
    assert cleanup >= 0
    # the old (buggy) padded billing would have charged
    # plan.segment_size per scan segment instead of the true count
    assert int(res.work.hook_ops) == \
        (1 + cleanup) * g.num_edges * (1 + lift)


def test_hostloop_unknown_method_raises():
    g = G.chain(5)
    with pytest.raises(ValueError, match="unknown method"):
        connected_components_hostloop(g.edges, g.num_nodes,
                                      method="adaptive")
