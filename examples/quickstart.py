"""Quickstart: the paper's adaptive Connected Components in 10 lines.

One front door — ``repro.Solver`` — routes every call through the
adaptive policy (the paper's 2|E|/|V| rule + a measured autotune
cache) and a pluggable backend registry, and the decision is
inspectable via ``plan().explain()`` BEFORE anything runs.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import Solver
from repro.core.unionfind import connected_components_oracle
from repro.graphs.generators import table1_scaled

# --- the 10-line intro ---------------------------------------------------
g = table1_scaled("usa-osm", scale=1 / 512, seed=0)
solver = Solver.open(g)                       # a session
print(solver.plan().explain())                # the adaptive decision
result = solver.solve()                       # CCResult(labels, work)
print(f"components: {solver.num_components():,} "
      f"(hook_ops={int(result.work.hook_ops):,})")
solver.insert([[0, g.num_nodes - 1]])         # streaming mutation
print(f"connected(0, |V|-1) after insert: "
      f"{solver.connected(0, g.num_nodes - 1)}")
# -------------------------------------------------------------------------


def method_sweep() -> None:
    """The Fig. 5 ladder through the same facade: force each backend,
    validate against the union-find oracle, compare work counters."""
    for name in ("usa-osm", "kron-logn21"):
        gr = table1_scaled(name, scale=1 / 512, seed=0)
        s = Solver.open(gr)
        oracle = connected_components_oracle(gr.edges, gr.num_nodes)
        print(f"\n=== {name}-scaled: |V|={gr.num_nodes:,} "
              f"|E|={gr.num_edges:,} avg_deg={gr.avg_degree:.2f} "
              f"auto->{s.plan().backend} ===")
        print(f"{'backend':<12} {'sync_rounds':>11} {'hook_ops':>12} "
              f"{'jump_sweeps':>11}")
        for backend in ("soman", "multijump", "atomic_hook", "adaptive",
                        "sampled"):
            res = s.solve(backend=backend)
            assert np.array_equal(np.asarray(res.labels), oracle), backend
            w = res.work
            print(f"{backend:<12} {int(w.sync_rounds):>11} "
                  f"{int(w.hook_ops):>12} {int(w.jump_sweeps):>11}")
        print("all backends match the union-find oracle ✓")
        # the spanning forest is a first-class product: |V| - C parent
        # edges recorded during the hook rounds, roots = component minima
        forest = s.spanning_forest()
        n_edges = int(np.sum(np.asarray(forest.parents)[:, 0] >= 0))
        print(f"spanning forest: {n_edges:,} tree edges "
              f"({gr.num_nodes - n_edges:,} roots)")


if __name__ == "__main__":
    method_sweep()
