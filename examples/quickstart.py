"""Quickstart: the paper's adaptive Connected Components in 30 lines.

Runs all four Hook–Compress variants on a scaled road network + a
power-law graph, validates against the union-find oracle, and prints the
work counters that explain the paper's speedups.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cc import METHODS, connected_components, num_components
from repro.core.unionfind import connected_components_oracle
from repro.graphs.generators import table1_scaled


def main() -> None:
    for name in ("usa-osm", "kron-logn21"):
        g = table1_scaled(name, scale=1 / 512, seed=0)
        print(f"\n=== {name}-scaled: |V|={g.num_nodes:,} "
              f"|E|={g.num_edges:,} avg_deg={g.avg_degree:.2f} ===")
        oracle = connected_components_oracle(g.edges, g.num_nodes)
        print(f"components: {num_components(oracle):,}")
        print(f"{'method':<12} {'sync_rounds':>11} {'hook_ops':>12} "
              f"{'jump_sweeps':>11}")
        for method in METHODS:
            res = connected_components(g.edges, g.num_nodes,
                                       method=method)
            assert np.array_equal(np.asarray(res.labels), oracle), method
            w = res.work
            print(f"{method:<12} {int(w.sync_rounds):>11} "
                  f"{int(w.hook_ops):>12} {int(w.jump_sweeps):>11}")
        print("all variants match the union-find oracle ✓")


if __name__ == "__main__":
    main()
