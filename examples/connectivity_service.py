"""Connectivity-as-a-service: multi-tenant live graphs under mixed
insert/delete/query traffic (DESIGN.md §7, §9).

Every tenant is a ``repro.Solver`` session under the hood (DESIGN.md
§10): the registry adds naming, stats, and version-stamped query
caching on top of the facade's policy routing — so the service stack
and a hand-held ``Solver`` behave identically by construction.

Two tenants share one registry — a power-law "social" graph (R-MAT)
and a high-diameter "road" grid. A stream of interleaved edge-insert,
edge-delete, and connectivity-query requests flows through the
slot-based service engine, which coalesces mutations per tenant and
microbatches same-shape query batches through shared jit cache
entries. The adaptive policy routes every mutation: the opening bulk
load goes through a static engine chosen from the graph's density,
later insert deltas are absorbed incrementally, and delete batches
tombstone + scope-recompute only the components they touched; queries
are answered from the live canonical label array — never a recompute.

    PYTHONPATH=src python examples/connectivity_service.py
"""
import numpy as np

from repro.connectivity import ConnectivityService, GraphRegistry
from repro.core.unionfind import DynamicConnectivityOracle
from repro.graphs.generators import grid_road, rmat


def main() -> None:
    rng = np.random.default_rng(0)
    tenants = {"social": rmat(7, 6, seed=1), "road": grid_road(18, seed=2)}

    registry = GraphRegistry()
    svc = ConnectivityService(registry, slots=16)
    oracles = {}
    for name, g in tenants.items():
        registry.create(name, g.num_nodes)
        oracles[name] = DynamicConnectivityOracle(g.num_nodes)

    n_rounds = 5
    splits = {name: np.array_split(rng.permutation(g.num_edges), n_rounds)
              for name, g in tenants.items()}

    for rnd in range(n_rounds):
        uids = {}
        for name, g in tenants.items():
            edges = np.asarray(g.edges)[splits[name][rnd]]
            svc.submit_insert(name, edges)
            oracles[name].insert(edges)
            if rnd:          # churn: retire a few live edges each round
                live = oracles[name].alive()
                kills = live[rng.integers(0, live.shape[0], 3)]
                svc.submit_delete(name, kills)
                oracles[name].delete(kills)
            pairs = rng.integers(0, g.num_nodes, (32, 2))
            uids[name] = (svc.submit_query(name, "same_component", pairs),
                          pairs)
            svc.submit_query(name, "count_components")
        finished = {r.uid: r for r in svc.run()}

        line = [f"round {rnd}:"]
        for name, g in tenants.items():
            # every answer must agree with a from-scratch union-find
            # oracle over the SURVIVING edges (queries see this
            # round's inserts and deletes)
            labels = oracles[name].labels()
            uid, pairs = uids[name]
            want = labels[pairs[:, 0]] == labels[pairs[:, 1]]
            assert np.array_equal(np.asarray(finished[uid].result), want)
            t = registry.get(name)
            line.append(f"{name}: v{t.version} "
                        f"{registry.count_components(name):4d} comps "
                        f"via {t.last_method:<18s}")
        print("  ".join(line))

    print("\nper-tenant registry stats:")
    for name, s in registry.stats().items():
        print(f"  {name:7s} inserts={s['inserts']} deletes={s['deletes']} "
              f"(absorbs={s['absorbs']} scoped={s['scoped_deletes']} "
              f"rebuilds={s['rebuilds']} "
              f"partition_changes={s['partition_changes']}) "
              f"queries={s['queries']} cache_hits={s['cache_hits']} "
              f"hook_ops={s['hook_ops']}")
    st = svc.stats
    print(f"service: {st['queries_served']} query requests in "
          f"{st['query_calls']} device calls, "
          f"{st['inserts_absorbed']} inserts in {st['insert_calls']} "
          f"coalesced absorbs, {st['deletes_absorbed']} deletes in "
          f"{st['delete_calls']} coalesced tombstone ticks, "
          f"{st['recomputes_avoided']} label recomputes avoided")

    # the component-size histogram, straight off the device
    hist = registry.component_histogram("social")
    bins = [f"2^{b}:{int(c)}" for b, c in enumerate(hist) if c]
    print(f"social component-size histogram: {' '.join(bins)}")


if __name__ == "__main__":
    main()
