"""Fleet serving: 32 tenants sharded across a device mesh with
pipelined ticks and merged fleet SLOs (DESIGN.md §15).

One ``FleetService`` is the whole story: ``admit()`` bin-packs each
tenant onto the least-loaded mesh device by predicted work (a "whale"
whose work crosses the shard threshold instead spans the WHOLE mesh
through the distributed backend), ``step()`` runs one pipelined fleet
tick — dispatch every shard's mutations, dispatch batched cross-tenant
query kernels, collect the PREVIOUS tick's answers — and ``slo()``
merges the per-device recorders with exact bucket-count sums.

The mesh here is fake (8 XLA host devices on CPU), which is exactly
the CI posture: the fleet's win is host-side economics — one stacked
label-plane dispatch per (shard, kind) instead of one dispatch + sync
per tenant — not parallel FLOPs.

    PYTHONPATH=src python examples/fleet_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np   # noqa: E402

from repro import obs                                       # noqa: E402
from repro.core.unionfind import DynamicConnectivityOracle  # noqa: E402
from repro.fleet import FleetService                        # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    n, n_tenants, whale_nodes = 512, 32, 1 << 15

    fleet = FleetService(slots_per_device=64, rebalance_every=0,
                         shard_threshold=whale_nodes)
    n_dev = len(fleet.devices)

    names = [f"tenant{i:02d}" for i in range(n_tenants)]
    oracles = {}
    for name in names:
        fleet.admit(name, n, expected_edges=n)
        oracles[name] = DynamicConnectivityOracle(n)
    fleet.admit("whale", whale_nodes, expected_edges=4 * whale_nodes)
    assert fleet.placement_of("whale") == "mesh"

    # opening bulk load: a random graph per packed tenant, a long
    # chain for the whale (the worst case for label propagation)
    for name in names:
        edges = rng.integers(0, n, (n // 2, 2)).astype(np.int32)
        fleet.submit_insert(name, edges)
        oracles[name].insert(edges)
    chain = np.stack([np.arange(whale_nodes - 1),
                      np.arange(1, whale_nodes)], 1).astype(np.int32)
    fleet.submit_insert("whale", chain)
    fleet.run()

    # mixed open-loop traffic: every tick queries every tenant, and a
    # rotating handful of tenants absorb an insert delta. Expected
    # answers snapshot the oracle at SUBMIT time — the engine runs the
    # mutation phase before the query phase within a tick, so a query
    # sees its own tick's inserts (the answer just arrives a tick
    # later, per the pipeline's double buffer).
    obs.enable(capacity=1 << 12)   # SLOs record only while tracing is on
    n_ticks, retired, expected = 6, [], {}
    for tick in range(n_ticks):
        for i, name in enumerate(names):
            if i % 8 == tick % 8:
                delta = rng.integers(0, n, (16, 2)).astype(np.int32)
                fleet.submit_insert(name, delta)
                oracles[name].insert(delta)
            pairs = rng.integers(0, n, (32, 2)).astype(np.int32)
            lab = oracles[name].labels()
            expected[(name, tick)] = lab[pairs[:, 0]] == lab[pairs[:, 1]]
            fleet.submit_query(name, "same_component", pairs)
        fleet.submit_query("whale", "same_component",
                           np.array([[0, whale_nodes - 1]], np.int32))
        retired.extend(fleet.step())
    retired.extend(fleet.run())   # drain the pipeline tail
    obs.disable()

    # every answer agrees with the union-find oracle (retirement is
    # FIFO per tenant, so the k-th answer is the tick-k query)
    checked, seq = 0, {}
    for r in retired:
        if r.kind != "same_component":
            continue
        assert r.error is None, r.error
        if r.tenant == "whale":
            assert bool(np.asarray(r.result)[0])   # chain is connected
            checked += 1
            continue
        tick = seq[r.tenant] = seq.get(r.tenant, -1) + 1
        np.testing.assert_array_equal(np.asarray(r.result),
                                      expected[(r.tenant, tick)])
        checked += 1
    assert checked == n_ticks * (n_tenants + 1)

    per_dev = [sum(1 for t in names if fleet.placement_of(t) == d)
               for d in range(n_dev)]
    slo = fleet.slo()
    print(f"devices={n_dev}  tenants={n_tenants}+whale  "
          f"packed per device={per_dev}")
    print(f"requests retired={len(retired)}  "
          f"query answers checked={checked}")
    print(f"fleet p50 query={slo.percentile(0.50) * 1e3:.2f} ms  "
          f"p99={slo.percentile(0.99) * 1e3:.2f} ms  "
          f"(merged across {n_dev} per-device recorders + mesh)")
    print("stats:", {k: v for k, v in fleet.stats.items() if v})


if __name__ == "__main__":
    main()
