"""CC as a first-class graph-pipeline feature + the distributed form.

Everything through the ``repro.Solver`` facade (DESIGN.md §10):

1. Generate a multi-component graph; label components with adaptive CC.
2. Use the labels the way the GNN pipeline does: keep the largest
   component, verify a molecule batch stays block-diagonal.
3. Run the per-round Pallas kernel backend (``backend="pallas"``;
   interpret mode on CPU, TPU target).
4. Run DISTRIBUTED CC over a device mesh (spatial segmentation — the
   paper's segments across chips; single-device mesh here, the 512-chip
   version is exercised by ``python -m repro.launch.dryrun --arch
   cc-adaptive``).

    PYTHONPATH=src python examples/cc_pipeline.py
"""
import numpy as np

import jax

from repro import Solver, solve
from repro.core.unionfind import connected_components_oracle
from repro.graphs.generators import disjoint_cliques, molecule_batch


def main() -> None:
    # 1: component labeling
    g = disjoint_cliques(num_cliques=6, clique_size=50)
    solver = Solver.open(g)
    labels = np.asarray(solver.solve().labels)
    sizes = {int(c): int((labels == c).sum()) for c in np.unique(labels)}
    print(f"6-clique graph -> {len(sizes)} components, sizes "
          f"{sorted(sizes.values())}")

    # 2: connectivity filtering for the data pipeline
    biggest = max(sizes, key=sizes.get)
    keep = labels == biggest
    print(f"largest-component filter keeps {keep.sum()} / "
          f"{g.num_nodes} nodes")

    mols = molecule_batch(num_graphs=8, nodes_per_graph=10,
                          edges_per_graph=14)
    mol_labels = np.asarray(solve(mols.edges, mols.num_nodes).labels)
    blocks = mol_labels // 10
    node_blocks = np.arange(mols.num_nodes) // 10
    assert (blocks == node_blocks).all(), \
        "component labels crossed molecule boundaries!"
    print("molecule batch verified block-diagonal via CC ✓")

    # 3: per-round Pallas kernel backend, same facade door
    got = np.asarray(solver.solve(backend="pallas").labels)
    assert np.array_equal(got, labels)
    print("Pallas hook/multi_jump kernel backend matches ✓")

    # 4: distributed CC (mesh of whatever devices exist)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    dist_solver = Solver.open(g, mesh=mesh)
    plan = dist_solver.plan()
    assert plan.backend == "distributed" and plan.reason == "sharded"
    dist = np.asarray(dist_solver.solve().labels)
    assert np.array_equal(
        dist, connected_components_oracle(g.edges, g.num_nodes))
    print(f"distributed CC over {mesh.devices.size} device(s) matches ✓")


if __name__ == "__main__":
    main()
