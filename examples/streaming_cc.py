"""Streaming connectivity through the facade: absorb edge insertions
without recomputing.

A stream of edge batches (think: new friendships, new road segments)
arrives against a fixed vertex set. A ``repro.Solver`` session routes
every batch through the adaptive policy (DESIGN.md §6, §10; Hong et
al.): small deltas are absorbed incrementally — a batch that lands
inside existing components costs zero hook rounds — while staying
bit-identical to a from-scratch run on the accumulated edge set.

Also shows the batched backend: the same shared adaptive core, vmapped
over a fleet of small graphs in one device program per shape bucket
(``Solver.solve_batch``; DESIGN.md §4).

    PYTHONPATH=src python examples/streaming_cc.py
"""
import numpy as np

from repro import Solver, solve
from repro.connectivity import count_components
from repro.core.unionfind import connected_components_oracle
from repro.graphs.generators import grid_road, rmat


def main() -> None:
    # 1: stream a road-ish graph in 6 insertion batches
    g = grid_road(24, seed=0)
    edges = np.asarray(g.edges)
    rng = np.random.default_rng(0)
    batches = np.array_split(rng.permutation(edges.shape[0]), 6)

    s = Solver.open(num_nodes=g.num_nodes)
    acc = np.zeros((0, 2), np.int32)
    full_hook_ops = 0
    for i, sel in enumerate(batches):
        s.insert(edges[sel])
        acc = np.concatenate([acc, edges[sel]], axis=0)
        full = solve(acc, g.num_nodes, method="adaptive")
        full_hook_ops += int(full.work.hook_ops)
        assert np.array_equal(np.asarray(s.labels),
                              np.asarray(full.labels))
        print(f"batch {i}: +{sel.size:4d} edges -> "
              f"{s.num_components():4d} components via {s.last_method} "
              f"(incremental == full recompute ✓)")

    want = connected_components_oracle(edges, g.num_nodes)
    assert np.array_equal(np.asarray(s.labels), want)
    saved = full_hook_ops / max(s.work["hook_ops"], 1)
    print(f"hook_ops: facade stream {s.work['hook_ops']} vs "
          f"{full_hook_ops} for per-batch full recompute "
          f"({saved:.1f}x less hook work)")

    # 2: a no-op batch (already-connected edges) is nearly free
    before = s.work["hook_rounds"]
    s.insert(edges[:64])                 # duplicates of absorbed edges
    print(f"re-inserting 64 known edges cost "
          f"{s.work['hook_rounds'] - before} hook rounds")

    # 3: batched backend — a fleet of small graphs, one device program
    fleet = [rmat(5, 3, seed=sd) for sd in range(32)]
    results = Solver.solve_batch(fleet)
    comps = [int(count_components(r.labels)) for r in results]
    for gr, r in zip(fleet, results):
        assert np.array_equal(
            np.asarray(r.labels),
            np.asarray(solve(gr.edges, gr.num_nodes).labels))
    print(f"batched CC over {len(fleet)} graphs (bit-identical to "
          f"per-graph runs ✓); component counts: "
          f"min={min(comps)} max={max(comps)}")


if __name__ == "__main__":
    main()
