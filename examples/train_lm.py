"""End-to-end LM training driver: a ~10M-parameter qwen-style model for
a few hundred steps through the REAL production stack — deterministic
pipeline + prefetch, gradient accumulation, async atomic checkpointing,
injected mid-run failure + automatic restart, cosine schedule.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import pipeline as dp
from repro.models import transformer as T
from repro.train import train_state
from repro.train.fault_tolerance import (SimulatedFailure, StepWatchdog,
                                         run_with_restarts)
from repro.train.optimizer import AdamWConfig, adamw, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=150)
    args = ap.parse_args()

    cfg = T.LMConfig(
        name="qwen-mini", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=704, vocab=4096, qkv_bias=True,
        dtype=jnp.float32, remat=False)
    print(f"model: {T.param_count(cfg) / 1e6:.1f}M params")

    opt = adamw(AdamWConfig(
        lr=cosine_schedule(3e-3, warmup=20, total=args.steps)))
    raw_step = jax.jit(
        train_state.make_train_step(
            lambda p, b: T.loss_fn(p, b, cfg), opt, accum_steps=2),
        donate_argnums=(0,))

    tripped = {"done": False}

    def step_fn(state, batch):
        s = int(state["step"])
        if args.fail_at and s == args.fail_at and not tripped["done"]:
            tripped["done"] = True
            print(f"  !! injected failure at step {s} — restarting "
                  f"from checkpoint")
            raise SimulatedFailure("chaos-monkey")
        return raw_step(state, {"tokens": jnp.asarray(batch["tokens"])})

    def stream_fn(start):
        return dp.make_stream(dp.lm_batches, 0, 16, 128, cfg.vocab,
                              start_step=start)

    losses = []

    def on_metrics(step, m):
        losses.append(float(np.asarray(m["loss"])))
        if step % 50 == 0:
            print(f"  step {step:4d}  loss {losses[-1]:.4f}")

    ckpt = os.path.join(tempfile.gettempdir(), "repro_train_lm")
    report = run_with_restarts(
        init_state_fn=lambda: train_state.create(
            T.init(jax.random.PRNGKey(0), cfg), opt),
        step_fn=step_fn, stream_fn=stream_fn, total_steps=args.steps,
        ckpt_dir=ckpt, ckpt_every=50, watchdog=StepWatchdog(),
        on_metrics=on_metrics)

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\ndone: {report.steps_run} steps ({report.restarts} restart)"
          f", loss {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not improve"
    assert report.restarts == (1 if args.fail_at else 0)
    print("training improved the loss and survived the failure ✓")


if __name__ == "__main__":
    main()
