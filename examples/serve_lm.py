"""Serving example: continuous batching with mixed-length requests.

A small gemma-style model (alternating local/global attention, ring +
full KV caches) serves a queue of requests through the slot engine:
finished requests release their slot mid-flight and queued ones are
prefilled into it while the others keep decoding.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serving.engine import Engine, generate


def main() -> None:
    cfg = T.LMConfig(
        name="gemma-mini", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=384, vocab=2048, window=16,
        layer_pattern="local_global", attn_softcap=50.0,
        final_softcap=30.0, post_norm=True, embed_scale=True,
        tie_embed=True, dtype=jnp.float32, remat=False)
    params = T.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    eng = Engine(params, cfg, slots=4, prompt_buf=32, cache_buf=96)
    n_req = 10
    for i in range(n_req):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(1, cfg.vocab, plen),
                   max_new=int(rng.integers(8, 24)))

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU)")

    # spot-check one request against standalone greedy decoding
    r = done[3]
    prompts = np.full((1, 32), -1, np.int32)
    prompts[0, :len(r.prompt)] = r.prompt
    ref = generate(params, cfg, prompts, max_new=len(r.out_tokens),
                   cache_buf=96)
    assert np.array_equal(ref[0], np.array(r.out_tokens)), \
        "continuous batching diverged from standalone decode"
    print("continuous-batching output == standalone greedy decode ✓")


if __name__ == "__main__":
    main()
